"""REPRO_FORCE_DEVICES -> XLA_FLAGS shim (the ONE copy of the rule).

``REPRO_FORCE_DEVICES=N`` splits the host CPU into N virtual jax devices —
how the org-sharded GAL engine, mesh tests, and multi-device serving run in
a CPU container. XLA reads ``XLA_FLAGS`` lazily when the backend is first
instantiated, so ``apply_force_devices()`` may run after ``import jax`` but
MUST run before the first jax operation / ``jax.devices()`` call: invoke it
at module top, ahead of any jax API use (tests/conftest.py,
repro/launch/serve.py, the benchmarks shard-scaling subprocess).
"""
from __future__ import annotations

import os


def apply_force_devices() -> None:
    n = os.environ.get("REPRO_FORCE_DEVICES")
    if n:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   f" --xla_force_host_platform_device_count"
                                   f"={n}")
