from repro.utils.pytree import tree_size, tree_bytes, tree_zeros_like, tree_add, tree_scale
from repro.utils.registry import Registry
