"""Minimal string -> factory registry (configs, local models, losses)."""
from __future__ import annotations

from typing import Callable, Dict, Generic, TypeVar

T = TypeVar("T")


class Registry(Generic[T]):
    def __init__(self, kind: str):
        self.kind = kind
        self._entries: Dict[str, T] = {}

    def register(self, name: str) -> Callable[[T], T]:
        def deco(obj: T) -> T:
            if name in self._entries:
                raise KeyError(f"duplicate {self.kind} registration: {name}")
            self._entries[name] = obj
            return obj

        return deco

    def get(self, name: str) -> T:
        if name not in self._entries:
            raise KeyError(
                f"unknown {self.kind} '{name}'; known: {sorted(self._entries)}"
            )
        return self._entries[name]

    def names(self):
        return sorted(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries
