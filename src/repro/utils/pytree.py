"""Small pytree helpers used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_size(tree) -> int:
    """Total number of array elements in a pytree."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays / ShapeDtypeStructs."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: x * s, tree)


def tree_global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in leaves))
