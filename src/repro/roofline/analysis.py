"""Roofline analysis from the compiled dry-run artifact (no real hardware).

Three terms per (arch x shape x mesh), in seconds:
    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

Sources: compiled.cost_analysis() for FLOPs/bytes; collective bytes parsed
from the compiled HLO text (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute operand sizes). XLA's cost analysis of a
GSPMD-partitioned module is per-partition, so terms divide by per-chip rates
only — verified in tests/test_roofline.py.

CAVEAT (scan trip counts): XLA's cost model counts a while-loop body ONCE.
Layer-stacked models run L layers via lax.scan, so raw HLO FLOPs undercount
by ~L. We report both the raw numbers and trip-count-corrected numbers using
the known layer count (``scan_correction``), and cross-check against the
analytic 6*N*D MODEL_FLOPS.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict

import numpy as np

from repro.configs.base import InputShape, ModelConfig


@dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    link_bw: float = 50e9               # bytes/s per ICI link


HW = Hardware()

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                       r"\[([0-9,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?[a-z0-9\[\],{}\s]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)


def _line_result_bytes(line: str) -> int:
    """Sum the byte sizes of all shapes appearing before the op name
    (the result shape(s) of the collective)."""
    head = line.split("=", 1)
    if len(head) != 2:
        return 0
    # result shapes live between '=' and the op call; operands after '('.
    rhs = head[1]
    op_pos = rhs.find("(")
    result_part = rhs[:op_pos] if op_pos >= 0 else rhs
    total = 0
    for dt, dims in _SHAPE_RE.findall(result_part):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes in the (per-partition) module."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # async pair: count the -start only
        kind = m.group(1).lower()
        out[kind] = out.get(kind, 0) + _line_result_bytes(line)
    return out


def gal_shard_round_collectives(n: int, k: int, m: int, rounds: int,
                                eval_ns=(), weight_epochs: int = 100,
                                block_size: int = 1, data_shards: int = 1,
                                dtype_bytes: int = 4,
                                alice_quadratic: bool = True
                                ) -> Dict[str, int]:
    """Expected per-partition collective bytes of the compiled org-sharded
    GAL fit (``core.engine.lower_shard_round`` -> ``hlo_stats.analyze``),
    decomposed so tests can reconcile the compiler's traffic with the
    protocol ledger (``core.protocol_sim.gal_round_bytes``):

      all_gather            step-3 fitted-value gather, (M, N/ds, K) result
                            per round. EXACT under every placement. The
                            ledger's train-set gather is the same tensor
                            counted once per data shard:
                            ``ledger_train_gather == data_shards * all_gather``.
      all_reduce_broadcast  step-2 residual psum from Alice's device,
                            (N/ds, K) per round. The ledger's broadcast is
                            per-receiver-link: ``ledger_broadcast ==
                            (m - 1) * data_shards * all_reduce_broadcast``
                            at fp32. NOTE ``residual_dtype="bf16"`` does NOT
                            shrink this number: XLA folds the bf16 upcast
                            into the all-reduce producer, so the simulated
                            collective stays f32 — the 2-byte width is a
                            wire-protocol (ledger) property of real
                            cross-org links, not of the single-host psum.
      all_reduce_direction  step-6 weighted org-sum of fitted values.
      all_reduce_evals      per-eval-set combines (weighted sums, so
                            (N_e, K) — the ledger instead books the
                            protocol's M per-org shipments, M * N_e * K).
      all_reduce_weight_fit step-4 distributed assistance-weight fit. For
                            block placement with the quadratic alice loss
                            (the alice_q=2 default) the fit runs on
                            per-block Gram statistics, so each epoch moves
                            ONLY the (M,) gradient psum per sharded mesh
                            axis — no (N, K) tensor crosses the mesh inside
                            the epoch loop. A non-quadratic alice loss
                            (``alice_quadratic=False``) keeps the
                            combine-and-psum objective: one forward (N/ds,
                            K) psum per epoch (its backward transpose is
                            eliminated by a stop_gradient identity) plus
                            the (M,) psums. Zero for 1:1 placement on an
                            un-sharded data axis — the weight fit is then
                            replicated.
      all_reduce            sum of the above. EXACT when data_shards == 1;
                            a LOWER bound when the data axis is sharded
                            (the psum'd global-mean loss adds a few bytes
                            of scalar sync per line-search/loss call that
                            we do not model).
      all_reduce_exact      whether ``all_reduce`` is exact or a bound.

    Verified against the compiled HLO in tests/test_roofline_engine.py."""
    if data_shards < 1 or n % data_shards:
        raise ValueError(f"data_shards {data_shards} must divide n {n}")
    db = dtype_bytes
    n_l = n // data_shards
    axes = (1 if block_size > 1 else 0) + (1 if data_shards > 1 else 0)
    if block_size > 1:
        if alice_quadratic and data_shards == 1:
            # Gram fast path: the epoch loop is collective-free except for
            # the per-axis (M,) gradient psum
            wfit_round = weight_epochs * (axes * m * db)
        else:
            wfit_round = weight_epochs * (n_l * k * db + axes * m * db)
    elif data_shards > 1:
        wfit_round = weight_epochs * (m * db)   # (M,) grad psum over "data"
    else:
        wfit_round = 0
    out = {
        "all_gather": rounds * m * n_l * k * db,
        "all_reduce_broadcast": rounds * n_l * k * db,
        "all_reduce_direction": rounds * n_l * k * db,
        "all_reduce_evals": rounds * sum(int(ne) * k * db for ne in eval_ns),
        "all_reduce_weight_fit": rounds * wfit_round,
        "all_reduce_exact": data_shards == 1,
    }
    out["all_reduce"] = (out["all_reduce_broadcast"]
                         + out["all_reduce_direction"]
                         + out["all_reduce_evals"]
                         + out["all_reduce_weight_fit"])
    return out


def model_flops(cfg: ModelConfig, shape: InputShape, train: bool = True) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D for training, 2*N_active*D for
    inference forward (D = tokens processed)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch * 1       # decode: one token
    return 2.0 * n * tokens


def roofline_terms(cost: Dict[str, float], collectives: Dict[str, int],
                   n_chips: int, hw: Hardware = HW,
                   scan_correction: float = 1.0) -> Dict[str, float]:
    """cost: compiled.cost_analysis() dict (per-partition module).
    Returns the three terms in seconds plus raw inputs."""
    flops = float(cost.get("flops", 0.0)) * scan_correction
    bytes_acc = float(cost.get("bytes accessed", 0.0)) * scan_correction
    coll = float(sum(collectives.values())) * scan_correction
    return {
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_acc,
        "collective_bytes_per_chip": coll,
        "t_compute": flops / hw.peak_flops,
        "t_memory": bytes_acc / hw.hbm_bw,
        "t_collective": coll / hw.link_bw,
        "n_chips": n_chips,
    }


def dominant_term(terms: Dict[str, float]) -> str:
    three = {k: terms[k] for k in ("t_compute", "t_memory", "t_collective")}
    return max(three, key=three.get)
