"""Loop-aware cost accounting from compiled HLO text.

XLA's cost_analysis() counts a while-loop body ONCE, which undercounts
layer-scan / grad-accumulation models by the trip product. This module parses
the compiled module text and walks the call graph multiplying by while-loop
trip counts:

  * FLOPs       — 2 * prod(result dims) * prod(contracting dim sizes) for
                  every dot / convolution (elementwise flops ignored: <1%).
  * bytes       — result bytes + resolvable operand bytes per instruction
                  (fusion-internal instructions are skipped: fused
                  intermediates never touch HBM).
  * collectives — result bytes of all-gather / all-reduce / reduce-scatter /
                  all-to-all / collective-permute, by kind.

Trip counts come from the loop-condition computation: jax lowers scan to a
while whose condition compares the counter against a constant.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(%[\w.\-]+|ENTRY\s+%?[\w.\-]+)\s*(?:\([^)]*\))?.*\{")
_OPND_RE = re.compile(r"%[\w.\-]+")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _result_part(rhs: str) -> str:
    pos = rhs.find("(")
    return rhs[:pos] if pos >= 0 else rhs


@dataclass
class Instruction:
    name: str
    rhs: str

    @property
    def op(self) -> str:
        m = re.search(r"\}?\s*([a-z][\w\-]*)\(", self.rhs)
        return m.group(1) if m else ""

    @property
    def result_bytes(self) -> int:
        return _shape_bytes(_result_part(self.rhs))

    @property
    def result_dims(self):
        m = _SHAPE_RE.search(_result_part(self.rhs))
        if not m:
            return None
        return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Computation:
    name: str
    instructions: List[Instruction] = field(default_factory=list)
    is_fusion_body: bool = False


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and ("->" in line or line.rstrip().endswith("{")):
            name = mc.group(1)
            if name.startswith("ENTRY"):
                name = "ENTRY"
            current = Computation(name=name)
            comps[name] = current
            continue
        if line.strip() == "}":
            continue
        if current is None:
            continue
        md = _DEF_RE.match(line)
        if md:
            current.instructions.append(Instruction(md.group(1), md.group(2)))
    return comps


def _dot_flops(ins: "Instruction", dims_of: Dict[str, list]) -> float:
    """2 * prod(result) * prod(contracting sizes). Operand shapes are looked
    up in the module-wide name -> dims map (HLO operands carry no shapes)."""
    rhs = ins.rhs
    res_dims = ins.result_dims or []
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
    if m is None:
        return 0.0
    cdims = [int(d) for d in m.group(1).split(",") if d]
    opnds = _OPND_RE.findall(rhs.split("(", 1)[1]) if "(" in rhs else []
    lhs_dims = dims_of.get(opnds[0]) if opnds else None
    if lhs_dims is None:
        return 0.0
    csize = 1
    for cd in cdims:
        if cd < len(lhs_dims):
            csize *= lhs_dims[cd]
    res = 1
    for d in res_dims:
        res *= d
    return 2.0 * res * csize


def _conv_flops(rhs: str) -> float:
    shapes = _SHAPE_RE.findall(rhs)
    if len(shapes) < 3:
        return 0.0
    res = math.prod(int(d) for d in shapes[0][1].split(",") if d)
    ker = math.prod(int(d) for d in shapes[2][1].split(",") if d)
    # flops ~ 2 * result_elems * kernel_elems / out_channels
    out_ch = int(shapes[0][1].split(",")[-1]) if shapes[0][1] else 1
    return 2.0 * res * ker / max(out_ch, 1)


def _trip_count(while_rhs: str, cond: Optional[Computation]) -> int:
    """Prefer XLA's known_trip_count annotation; fall back to the largest
    integer constant in the loop condition (the scan counter bound)."""
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', while_rhs)
    if m:
        return int(m.group(1))
    best = 1
    if cond is not None:
        for ins in cond.instructions:
            for mm in re.finditer(r"constant\((\d+)\)", ins.rhs):
                best = max(best, int(mm.group(1)))
    return best


@dataclass
class Stats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collectives: Dict[str, float] = field(default_factory=dict)

    def scaled(self, k: float) -> "Stats":
        return Stats(self.flops * k, self.bytes_accessed * k,
                     {n: v * k for n, v in self.collectives.items()})

    def __iadd__(self, o: "Stats"):
        self.flops += o.flops
        self.bytes_accessed += o.bytes_accessed
        for n, v in o.collectives.items():
            self.collectives[n] = self.collectives.get(n, 0.0) + v
        return self

    @property
    def collective_bytes(self) -> float:
        return float(sum(self.collectives.values()))


def _called(rhs: str, attr: str) -> Optional[str]:
    m = re.search(attr + r"=(%[\w.\-]+)", rhs)
    return m.group(1) if m else None


def analyze(text: str) -> Stats:
    comps = parse_hlo(text)
    # instruction-name -> result bytes / dims (operand resolution)
    defined: Dict[str, int] = {}
    dims_of: Dict[str, list] = {}
    for comp in comps.values():
        for ins in comp.instructions:
            defined[ins.name] = ins.result_bytes
            rd = ins.result_dims
            if rd is not None:
                dims_of[ins.name] = rd

    memo: Dict[str, Stats] = {}

    def walk(name: str) -> Stats:
        if name in memo:
            return memo[name]
        memo[name] = Stats()  # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        total = Stats()
        for ins in comp.instructions:
            op = ins.op
            rhs = ins.rhs
            if op == "while":
                body = _called(rhs, "body")
                cond = _called(rhs, "condition")
                trips = _trip_count(rhs, comps.get(cond))
                inner = Stats()
                if body:
                    inner += walk(body)
                if cond in comps:
                    inner += walk(cond)
                total += inner.scaled(max(trips, 1))
                continue
            if op in ("fusion", "call", "custom-call", "map", "reduce",
                      "reduce-window", "scatter", "select-and-scatter",
                      "sort", "conditional"):
                # fusion bodies: count dots inside (rare), skip their memory
                # (fused intermediates never hit HBM — the fusion line itself
                # contributes its operand/result bytes below)
                callee = _called(rhs, "calls") or _called(rhs, "to_apply")
                if callee and callee in comps:
                    inner = walk(callee)
                    total += Stats(inner.flops, 0.0, dict(inner.collectives))
            if op == "dot":
                total += Stats(flops=_dot_flops(ins, dims_of))
            elif op == "convolution":
                total += Stats(flops=_conv_flops(rhs))
            m = re.search(r"\b(all-gather|all-reduce|reduce-scatter|"
                          r"all-to-all|collective-permute)(-start)?\(", rhs)
            if m and "-done(" not in rhs:
                total += Stats(collectives={m.group(1): float(ins.result_bytes)})
            # memory: result + resolvable operands (top-level ops only)
            opnds = _OPND_RE.findall(rhs.split("(", 1)[1]) if "(" in rhs else []
            if op == "dynamic-update-slice":
                # in-place: traffic = slice written (+read), not the buffer
                upd = defined.get(opnds[1], 0) if len(opnds) > 1 else 0
                total += Stats(bytes_accessed=float(2 * upd))
            elif op == "dynamic-slice":
                total += Stats(bytes_accessed=float(2 * ins.result_bytes))
            elif op == "fusion":
                # in-place loop-stash fusions (DUS pattern): an operand the
                # same size as the result is aliased, traffic is only the
                # update inputs — count those twice (read + write)
                ob = [defined.get(o, 0) for o in opnds[:8]]
                if ins.result_bytes > (64 << 20) and ins.result_bytes in ob:
                    others = sum(b for b in ob if b != ins.result_bytes)
                    total += Stats(bytes_accessed=float(2 * others))
                else:
                    total += Stats(
                        bytes_accessed=float(ins.result_bytes + sum(ob)))
            else:
                opnd_bytes = sum(defined.get(o, 0) for o in opnds[:8])
                total += Stats(
                    bytes_accessed=float(ins.result_bytes + opnd_bytes))
        memo[name] = total
        return total

    return walk("ENTRY")
