from repro.roofline.analysis import (
    collective_bytes_from_hlo, roofline_terms, model_flops, HW,
)
