"""qwen3-1.7b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family]."""
from repro.configs.base import ModelConfig, register_arch

FULL = ModelConfig(
    arch="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=6144, vocab=151936, qk_norm=True, rope_theta=1e6,
    act="swiglu", norm="rmsnorm", source="hf:Qwen/Qwen3-8B",
)

SMOKE = ModelConfig(
    arch="qwen3-1.7b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab=512, qk_norm=True,
    act="swiglu", norm="rmsnorm", dtype="float32",
)

register_arch("qwen3-1.7b")((FULL, SMOKE))
