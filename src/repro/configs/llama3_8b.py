"""llama3-8b [dense] — GQA, 128k vocab [arXiv:2407.21783]."""
from repro.configs.base import ModelConfig, register_arch

FULL = ModelConfig(
    arch="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, rope_theta=500000.0,
    act="swiglu", norm="rmsnorm", source="arXiv:2407.21783",
)

SMOKE = ModelConfig(
    arch="llama3-8b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab=512, rope_theta=500000.0,
    act="swiglu", norm="rmsnorm", dtype="float32",
)

register_arch("llama3-8b")((FULL, SMOKE))
