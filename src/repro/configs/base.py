"""Architecture config schema + registry.

Every assigned architecture registers a FULL config (exact numbers from the
task's public-pool citation) and a SMOKE config (<=2 layers, d_model<=512,
<=4 experts) for CPU tests. Input shapes are registered alongside.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

from repro.utils.registry import Registry

ARCHS: Registry = Registry("architecture")


@dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                      # dense | moe | vlm | hybrid | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    # attention flavor
    qk_norm: bool = False
    rope_theta: float = 1e4
    window: Optional[int] = None     # sliding-window size (long-context variant)
    # MoE
    moe_experts: int = 0
    moe_topk: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / rwkv6)
    ssm_state: int = 0
    ssm_heads: int = 0               # mamba2 value heads; 0 = derive
    ssm_expand: int = 2
    conv_width: int = 4
    # layer pattern: "attn" uniform default; hybrid uses a repeating unit
    block_unit: Tuple[str, ...] = ("attn",)
    shared_attn: bool = False        # zamba2: one shared attn block reused
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    is_encoder_decoder: bool = False
    # modality frontend STUB (vlm / audio): model consumes embeddings directly
    frontend: Optional[str] = None   # "vision" | "audio" | None
    num_patches: int = 0             # vlm: image-patch embeddings per sample
    num_frames: int = 0              # audio: frame embeddings per sample
    # attention memory policy: 0 = dense scores; >0 = online-softmax over
    # KV chunks of this size (pure-JAX flash; the launcher sets this for the
    # big shapes so score temporaries stay bounded)
    attn_chunk: int = 0
    # misc
    act: str = "swiglu"              # swiglu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    scan_layers: bool = True         # stack+scan homogeneous layers
    remat: bool = False              # activation checkpointing in scan body
    remat_group: bool = False        # 2-level (sqrt-L) checkpointing
    source: str = ""                 # citation from the assignment

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // max(self.n_heads, 1)

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm" and "attn" not in self.block_unit

    def with_window(self, window: int) -> "ModelConfig":
        return replace(self, window=window)

    def param_count(self) -> int:
        """Analytic parameter count (used in roofline MODEL_FLOPS = 6ND)."""
        d, ff, v, hd = self.d_model, self.d_ff, self.vocab, self.hd
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        unit = self.block_unit
        n_units = self.n_layers // max(len([b for b in unit if b != "shared_attn"]), 1) \
            if "shared_attn" in unit else self.n_layers
        attn_p = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.act == "swiglu":
            mlp_p = 3 * d * ff
        else:
            mlp_p = 2 * d * ff
        total = emb
        for i in range(self.n_layers):
            kind = self.block_unit[i % len(self.block_unit)] \
                if len(self.block_unit) > 1 else self.block_unit[0]
            if kind == "attn":
                total += attn_p
                if self.is_moe:
                    total += self.moe_experts * mlp_p + d * self.moe_experts
                else:
                    total += mlp_p
            elif kind == "mamba":
                d_in = self.ssm_expand * d
                total += d * (2 * d_in + 2 * self.ssm_state +
                              max(self.ssm_heads, 1)) + d_in * d + d_in
            elif kind == "rwkv":
                total += 4 * d * d + d * ff * 2  # tmix + cmix approx
        if self.shared_attn:
            total += attn_p + mlp_p  # one shared block
        if self.is_encoder_decoder:
            # encoder layers (attn + gelu mlp) + decoder cross-attn
            total += self.encoder_layers * (attn_p + 2 * d * ff)
            total += self.n_layers * attn_p  # cross-attn per decoder layer
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k of experts)."""
        if not self.is_moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        mlp_p = 3 * d * ff if self.act == "swiglu" else 2 * d * ff
        inactive = self.n_layers * (self.moe_experts - self.moe_topk) * mlp_p
        return int(self.param_count() - inactive)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def register_arch(name: str):
    return ARCHS.register(name)


def get_arch(name: str, smoke: bool = False) -> ModelConfig:
    full, smoke_cfg = ARCHS.get(name)
    return smoke_cfg if smoke else full


def arch_names():
    return ARCHS.names()
