"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242]. 54 mamba2 layers; one shared attn+MLP block applied
after every 6th mamba layer (9 applications, shared params)."""
from repro.configs.base import ModelConfig, register_arch

FULL = ModelConfig(
    arch="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000,
    ssm_state=64, ssm_heads=80, ssm_expand=2, conv_width=4,
    block_unit=("mamba", "mamba", "mamba", "mamba", "mamba", "mamba",
                "shared_attn"),
    shared_attn=True, window=4096,   # shared attn uses a window for long ctx
    act="swiglu", norm="rmsnorm", source="arXiv:2411.15242",
)

SMOKE = ModelConfig(
    arch="zamba2-2.7b-smoke", family="hybrid",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab=512,
    ssm_state=16, ssm_heads=8, ssm_expand=2, conv_width=4,
    block_unit=("mamba", "mamba", "shared_attn"),
    shared_attn=True, act="swiglu", norm="rmsnorm", dtype="float32",
)

register_arch("zamba2-2.7b")((FULL, SMOKE))
