"""dbrx-132b [moe] — 16 experts top-4, fine-grained [hf:databricks/dbrx-base]."""
from repro.configs.base import ModelConfig, register_arch

FULL = ModelConfig(
    arch="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352,
    moe_experts=16, moe_topk=4, capacity_factor=1.25,
    rope_theta=500000.0, act="swiglu", norm="rmsnorm",
    source="hf:databricks/dbrx-base",
)

SMOKE = ModelConfig(
    arch="dbrx-132b-smoke", family="moe",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512,
    moe_experts=4, moe_topk=2, capacity_factor=1.5,
    act="swiglu", norm="rmsnorm", dtype="float32",
)

register_arch("dbrx-132b")((FULL, SMOKE))
