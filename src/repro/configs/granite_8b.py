"""granite-8b [dense] — llama-arch, code [arXiv:2405.04324]."""
from repro.configs.base import ModelConfig, register_arch

FULL = ModelConfig(
    arch="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=49152, rope_theta=10000.0,
    act="swiglu", norm="rmsnorm", source="arXiv:2405.04324",
)

SMOKE = ModelConfig(
    arch="granite-8b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=512, vocab=512, act="swiglu", norm="rmsnorm", dtype="float32",
)

register_arch("granite-8b")((FULL, SMOKE))
