"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.configs.base import ModelConfig, register_arch

FULL = ModelConfig(
    arch="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab=32064,
    moe_experts=16, moe_topk=2, capacity_factor=1.25,
    rope_theta=10000.0, act="swiglu", norm="layernorm",
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)

SMOKE = ModelConfig(
    arch="phi3.5-moe-smoke", family="moe",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512,
    moe_experts=4, moe_topk=2, capacity_factor=1.5,
    act="swiglu", norm="layernorm", dtype="float32",
)

register_arch("phi3.5-moe-42b-a6.6b")((FULL, SMOKE))
