"""pixtral-12b [vlm] — pixtral-ViT + mistral-nemo decoder
[hf:mistralai/Pixtral-12B-2409]. Vision encoder is a STUB: input_specs
provides patch embeddings; the projector + language decoder are real."""
from repro.configs.base import ModelConfig, register_arch

FULL = ModelConfig(
    arch="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072, rope_theta=1e6,
    frontend="vision", num_patches=1024,
    act="swiglu", norm="rmsnorm", source="hf:mistralai/Pixtral-12B-2409",
)

SMOKE = ModelConfig(
    arch="pixtral-12b-smoke", family="vlm",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab=512, frontend="vision", num_patches=16,
    act="swiglu", norm="rmsnorm", dtype="float32",
)

register_arch("pixtral-12b")((FULL, SMOKE))
