"""rwkv6-7b [ssm] — Finch, data-dependent decay, attention-free
[arXiv:2404.05892]."""
from repro.configs.base import ModelConfig, register_arch

FULL = ModelConfig(
    arch="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,  # 64 wkv heads (hd 64)
    d_ff=14336, vocab=65536,
    block_unit=("rwkv",),
    act="swiglu", norm="layernorm", source="arXiv:2404.05892",
)

SMOKE = ModelConfig(
    arch="rwkv6-7b-smoke", family="ssm",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab=512, block_unit=("rwkv",),
    act="swiglu", norm="layernorm", dtype="float32",
)

register_arch("rwkv6-7b")((FULL, SMOKE))
