"""Architecture config registry. Importing this package registers all 10
assigned architectures plus the paper's own tabular/image settings."""
from repro.configs.base import (
    ARCHS, SHAPES, InputShape, ModelConfig, arch_names, get_arch,
)
# register all assigned architectures
from repro.configs import (  # noqa: F401
    llama3_8b, dbrx_132b, pixtral_12b, stablelm_1_6b, zamba2_2_7b,
    phi35_moe, granite_8b, qwen3_1_7b, whisper_medium, rwkv6_7b,
)

ALL_ARCHS = arch_names()
assert len(ALL_ARCHS) == 10, ALL_ARCHS
