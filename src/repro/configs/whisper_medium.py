"""whisper-medium [audio] — enc-dec, conv frontend STUB [arXiv:2212.04356].

input_specs supplies precomputed mel/conv frame embeddings (B, 1500, d);
encoder (24L bidirectional) + decoder (24L causal + cross-attn) are real.
Decode at 32k/500k positions is a structural exercise (real whisper caps at
448 decoder positions) — noted in DESIGN.md."""
from repro.configs.base import ModelConfig, register_arch

FULL = ModelConfig(
    arch="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    is_encoder_decoder=True, encoder_layers=24,
    frontend="audio", num_frames=1500,
    act="gelu", norm="layernorm",
    source="arXiv:2212.04356",
)

SMOKE = ModelConfig(
    arch="whisper-medium-smoke", family="audio",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab=512,
    is_encoder_decoder=True, encoder_layers=2,
    frontend="audio", num_frames=64,
    act="gelu", norm="layernorm", dtype="float32",
)

register_arch("whisper-medium")((FULL, SMOKE))
