"""stablelm-1.6b [dense] — [hf:stabilityai/stablelm-2-1_6b]."""
from repro.configs.base import ModelConfig, register_arch

FULL = ModelConfig(
    arch="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=5632, vocab=100352, rope_theta=10000.0,
    act="swiglu", norm="layernorm", source="hf:stabilityai/stablelm-2-1_6b",
)

SMOKE = ModelConfig(
    arch="stablelm-1.6b-smoke", family="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab=512, act="swiglu", norm="layernorm", dtype="float32",
)

register_arch("stablelm-1.6b")((FULL, SMOKE))
