"""Unified architecture assembly for all 10 assigned configs.

One `Transformer` namespace of pure functions covering:
  dense GQA LMs          (llama3 / granite / stablelm / qwen3)
  capacity-routed MoE    (dbrx / phi3.5-moe)
  VLM token+patch decode (pixtral — vision frontend stubbed to embeddings)
  hybrid Mamba2 + shared attention (zamba2)
  attention-free RWKV6   (rwkv6-7b)
  encoder-decoder audio  (whisper — conv/mel frontend stubbed to embeddings)

Homogeneous layer stacks are stored stacked (L, ...) and executed with
jax.lax.scan (small HLO for the 512-device dry-run); zamba2 scans its
repeating unit. ``remat`` wraps scan bodies in jax.checkpoint.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import pspec
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    apply_mlp, apply_norm, dtype_of, embed_tokens, init_embedding, init_mlp,
    init_norm, unembed,
)


# =============================================================== param init
def _init_attn_block(rng, cfg: ModelConfig):
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    p = {
        "ln1": init_norm(cfg, cfg.d_model),
        "attn": attn.init_attention(k1, cfg),
        "ln2": init_norm(cfg, cfg.d_model),
    }
    if cfg.is_moe:
        p["moe"] = moe_lib.init_moe(k2, cfg)
    else:
        p["mlp"] = init_mlp(k3, cfg)
    return p


def _init_mamba_block(rng, cfg: ModelConfig):
    k1, _ = jax.random.split(rng)
    return {"ln": init_norm(cfg, cfg.d_model),
            "mamba": ssm_lib.init_mamba(k1, cfg)}


def _init_rwkv_block(rng, cfg: ModelConfig):
    k1, k2 = jax.random.split(rng)
    return {"ln1": init_norm(cfg, cfg.d_model),
            "tmix": rwkv_lib.init_rwkv_tmix(k1, cfg),
            "ln2": init_norm(cfg, cfg.d_model),
            "cmix": rwkv_lib.init_rwkv_cmix(k2, cfg)}


def _stack(init_fn, rng, n: int):
    keys = jax.random.split(rng, n)
    return jax.vmap(init_fn)(keys)


def init_params(rng: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(rng, 8)
    params: Dict[str, Any] = {"embed": init_embedding(ks[0], cfg),
                              "ln_f": init_norm(cfg, cfg.d_model)}
    unit = cfg.block_unit
    if unit == ("attn",):
        params["layers"] = _stack(lambda k: _init_attn_block(k, cfg),
                                  ks[1], cfg.n_layers)
    elif unit == ("rwkv",):
        params["layers"] = _stack(lambda k: _init_rwkv_block(k, cfg),
                                  ks[1], cfg.n_layers)
    elif "mamba" in unit:  # zamba2-style hybrid
        per_unit = sum(1 for b in unit if b == "mamba")
        n_units = cfg.n_layers // per_unit
        params["mamba_units"] = _stack(
            lambda k: _stack(lambda k2: _init_mamba_block(k2, cfg), k, per_unit),
            ks[1], n_units,
        )
        if cfg.shared_attn:
            params["shared_attn"] = _init_attn_block(ks[2], cfg)
    else:
        raise ValueError(f"unsupported block unit {unit}")

    if cfg.is_encoder_decoder:
        def enc_block(k):
            k1, k2 = jax.random.split(k)
            return {"ln1": init_norm(cfg, cfg.d_model),
                    "attn": attn.init_attention(k1, cfg),
                    "ln2": init_norm(cfg, cfg.d_model),
                    "mlp": init_mlp(k2, cfg)}

        params["encoder"] = _stack(enc_block, ks[3], cfg.encoder_layers)
        params["enc_ln_f"] = init_norm(cfg, cfg.d_model)

        def cross_block(k):
            return {"ln": init_norm(cfg, cfg.d_model),
                    "attn": attn.init_attention(k, cfg, cross=True)}

        params["cross"] = _stack(cross_block, ks[4], cfg.n_layers)
    if cfg.frontend == "vision":
        # projector from (stub) vision embeddings to d_model
        params["proj"] = (jax.random.normal(ks[5], (cfg.d_model, cfg.d_model),
                                            jnp.float32)
                          * cfg.d_model ** -0.5).astype(dtype_of(cfg))
    return params


# ============================================================ forward (train)
def _attn_block_fwd(block, cfg: ModelConfig, x, positions, *, causal=True,
                    window=None, flash=False):
    h = attn.attention_train(block["attn"], cfg, apply_norm(block["ln1"], x),
                             positions, causal=causal, window=window,
                             flash=flash)
    x = x + h
    hin = apply_norm(block["ln2"], x)
    if cfg.is_moe:
        h, aux = moe_lib.apply_moe(block["moe"], cfg, hin)
    else:
        h, aux = apply_mlp(block["mlp"], hin, cfg.act), jnp.zeros((), jnp.float32)
    return x + h, aux


def _rwkv_block_fwd(block, cfg: ModelConfig, x):
    x = x + rwkv_lib.rwkv_tmix_train(block["tmix"], cfg,
                                     apply_norm(block["ln1"], x))
    x = x + rwkv_lib.rwkv_cmix(block["cmix"], apply_norm(block["ln2"], x))
    return x, jnp.zeros((), jnp.float32)


def _mamba_block_fwd(block, cfg: ModelConfig, x):
    return x + ssm_lib.mamba_train(block["mamba"], cfg,
                                   apply_norm(block["ln"], x))


def _group_of(n: int) -> int:
    """Divisor of n nearest sqrt(n) (2-level remat group size)."""
    import math
    best, target = 1, math.sqrt(n)
    for d in range(1, n + 1):
        if n % d == 0 and abs(d - target) < abs(best - target):
            best = d
    return best


@jax.custom_vjp
def _stash_barrier(x):
    return jax.lax.optimization_barrier(x)


def _stash_barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _stash_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


# optimization_barrier has no differentiation rule in this jax version; the
# custom_vjp is the identity map with the barrier kept on both passes, so the
# stash-dtype pinning in _scan_layers survives value_and_grad.
_stash_barrier.defvjp(_stash_barrier_fwd, _stash_barrier_bwd)

# It lacks a batching rule too, which the fused GAL engine needs to vmap one
# architecture over org-stacked params. The barrier is elementwise-identity,
# so batch dims pass straight through.
try:
    from jax._src.lax.lax import optimization_barrier_p as _barrier_p
    from jax.interpreters import batching as _batching

    if _barrier_p not in _batching.primitive_batchers:
        def _barrier_batcher(batched_args, batch_dims):
            outs = _barrier_p.bind(*batched_args)
            return outs, batch_dims

        _batching.primitive_batchers[_barrier_p] = _barrier_batcher
except (ImportError, AttributeError):  # future jax: rules exist upstream
    pass


def _scan_layers(layers, body, x, aux0, remat: bool, group: bool = False):
    """Layer-stack execution. With remat: TWO-LEVEL (sqrt-L) checkpointing —
    an outer scan over G groups stashes only group-boundary activations; each
    group's inner scan re-stashes its layers transiently during backward.
    Cuts the dominant (L, B, S, d) stash to ~(G + L/G) layers' worth at the
    cost of one extra forward recompute (+~25% FLOPs), the standard
    memory-optimal remat schedule."""
    n_layers = jax.tree_util.tree_leaves(layers)[0].shape[0]
    fn = jax.checkpoint(body) if remat else body

    def scan_body(carry, layer):
        x, aux = carry
        # barrier pins the stash dtype: without it XLA hoists the backward's
        # first f32 convert of x into the per-layer stash, doubling it
        x = _stash_barrier(x)
        x, a = fn(layer, x)
        return (x, aux + a), None

    g = _group_of(n_layers) if (remat and group) else 1
    if remat and group and 1 < g < n_layers:
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape(g, n_layers // g, *a.shape[1:]), layers)

        @jax.checkpoint
        def group_fn(carry, group_layers):
            return jax.lax.scan(scan_body, carry, group_layers)

        (x, aux), _ = jax.lax.scan(group_fn, (x, aux0), grouped)
        return x, aux
    (x, aux), _ = jax.lax.scan(scan_body, (x, aux0), layers)
    return x, aux


def _decoder_stack(params, cfg: ModelConfig, x, positions, *, flash=False,
                   encoder_out=None):
    """Run the configured layer stack on embeddings x (B, S, d)."""
    # The residual-stream layout is anchored by REPLICATING the token table
    # (see sharding.param_pspec): the gather then yields batch-sharded,
    # d-replicated x directly. Constraining x here instead would force a
    # d-reshard inside the microbatch scan, which both costs ~290 GiB of
    # activation all-gathers per step AND trips an XLA SPMD verifier bug.
    aux = jnp.zeros((), jnp.float32)
    unit = cfg.block_unit
    if unit == ("attn",):
        if cfg.is_encoder_decoder:
            # scan over zipped (self-attn layer, cross-attn layer) stacks
            def encdec_body(layer_cross, xx):
                layer, cross = layer_cross
                h = attn.attention_train(
                    layer["attn"], cfg, apply_norm(layer["ln1"], xx),
                    positions, causal=True, window=cfg.window, flash=flash)
                xx = xx + h
                xx = xx + attn.attention_train(
                    cross["attn"], cfg, apply_norm(cross["ln"], xx), positions,
                    kv_src=encoder_out)
                xx = xx + apply_mlp(layer["mlp"], apply_norm(layer["ln2"], xx),
                                    cfg.act)
                return xx, jnp.zeros((), jnp.float32)

            return _scan_layers((params["layers"], params["cross"]),
                                encdec_body, x, aux, cfg.remat,
                                cfg.remat_group)
        body = lambda layer, xx: _attn_block_fwd(
            layer, cfg, xx, positions, causal=True, window=cfg.window,
            flash=flash)
        return _scan_layers(params["layers"], body, x, aux, cfg.remat,
                            cfg.remat_group)
    if unit == ("rwkv",):
        body = lambda layer, xx: _rwkv_block_fwd(layer, cfg, xx)
        return _scan_layers(params["layers"], body, x, aux, cfg.remat,
                            cfg.remat_group)
    # hybrid: scan units of [mamba x per_unit (+ shared attn)]; each block
    # is checkpointed so the quadratic intra-chunk SSD temporaries are
    # rematerialized instead of stashed (measured 131 GiB/device without)
    shared = params.get("shared_attn")
    mamba_fwd = (jax.checkpoint(lambda l, xx: _mamba_block_fwd(l, cfg, xx))
                 if cfg.remat else (lambda l, xx: _mamba_block_fwd(l, cfg, xx)))
    attn_fwd = lambda blk, xx: _attn_block_fwd(
        blk, cfg, xx, positions, causal=True, window=cfg.window, flash=flash)
    if cfg.remat:
        attn_fwd = jax.checkpoint(attn_fwd)

    def unit_body(carry, unit_params):
        x, aux = carry

        def mamba_body(xx, layer):
            return mamba_fwd(layer, xx), None

        x, _ = jax.lax.scan(mamba_body, x, unit_params)
        if shared is not None:
            x, a = attn_fwd(shared, x)
            aux = aux + a
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(unit_body, (x, aux), params["mamba_units"])
    return x, aux


def encode(params, cfg: ModelConfig, frames):
    """Whisper encoder over stub frame embeddings (B, F, d) -> (B, F, d)."""
    positions = jnp.broadcast_to(
        jnp.arange(frames.shape[1], dtype=jnp.int32), frames.shape[:2])
    x = frames.astype(dtype_of(cfg))

    def body(layer, xx):
        h = attn.attention_train(layer["attn"], cfg,
                                 apply_norm(layer["ln1"], xx), positions,
                                 causal=False)
        xx = xx + h
        return xx + apply_mlp(layer["mlp"], apply_norm(layer["ln2"], xx),
                              cfg.act), jnp.zeros((), jnp.float32)

    x, _ = _scan_layers(params["encoder"], body, x,
                        jnp.zeros((), jnp.float32), cfg.remat)
    return apply_norm(params["enc_ln_f"], x)


def apply(params, cfg: ModelConfig, tokens, *, patches=None, frames=None,
          flash: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward.

    tokens: (B, S_text) int32. patches: VLM stub embeddings (B, P, d).
    frames: audio stub embeddings (B, F, d) for the enc-dec arch.
    Returns (logits (B, S_total, vocab) f32, aux_loss).
    """
    x = embed_tokens(params["embed"], tokens).astype(dtype_of(cfg))
    if cfg.frontend == "vision" and patches is not None:
        pe = patches.astype(dtype_of(cfg)) @ params["proj"]
        x = jnp.concatenate([pe, x], axis=1)       # image tokens first
    # NOTE: constraining x right after the token gather trips an XLA SPMD
    # verifier bug (dynamic-slice size mismatch) when the gather sits inside
    # the grad-accumulation scan; propagation handles it fine unconstrained.
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2])
    encoder_out = None
    if cfg.is_encoder_decoder:
        if frames is None:
            raise ValueError("enc-dec arch requires frames")
        encoder_out = encode(params, cfg, frames)
    x, aux = _decoder_stack(params, cfg, x, positions, flash=flash,
                            encoder_out=encoder_out)
    x = apply_norm(params["ln_f"], x)
    # logits stay in the compute dtype: f32 logits would push f32 cotangents
    # through the whole backward pass and double the remat stash (measured:
    # 12 GiB/device on stablelm train_4k; see EXPERIMENTS.md SS Perf). Losses
    # upcast internally.
    logits = unembed(params["embed"], x)
    logits = pspec.constrain(
        logits, P(pspec.batch_axis(x.shape[0]), None,
                  pspec.model_axis(cfg.vocab)))
    return logits, aux


# ================================================================= decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               encoder_out: Optional[jnp.ndarray] = None) -> Dict[str, Any]:
    dt = dtype_of(cfg)
    unit = cfg.block_unit
    cache: Dict[str, Any] = {}
    if unit == ("attn",):
        def one(_):
            return attn.init_kv_cache(cfg, batch, max_len, dt)

        cache["attn"] = jax.vmap(one)(jnp.arange(cfg.n_layers))
    elif unit == ("rwkv",):
        def one(_):
            return rwkv_lib.init_rwkv_cache(cfg, batch, dt)

        cache["rwkv"] = jax.vmap(one)(jnp.arange(cfg.n_layers))
    else:
        per_unit = sum(1 for b in unit if b == "mamba")
        n_units = cfg.n_layers // per_unit

        def one_unit(_):
            def one(_):
                return ssm_lib.init_mamba_cache(cfg, batch, dt)

            return jax.vmap(one)(jnp.arange(per_unit))

        cache["mamba"] = jax.vmap(one_unit)(jnp.arange(n_units))
        if cfg.shared_attn:
            def one(_):
                return attn.init_kv_cache(cfg, batch, max_len, dt)

            cache["shared_attn"] = jax.vmap(one)(jnp.arange(n_units))
    if cfg.is_encoder_decoder:
        if encoder_out is None:
            raise ValueError("enc-dec cache needs encoder_out")
        cache["encoder_out"] = encoder_out
    return cache


def _attn_block_decode(block, cfg, x, layer_cache, cross=None, cross_params=None):
    h, new_cache = attn.attention_decode(
        block["attn"], cfg, apply_norm(block["ln1"], x), layer_cache)
    x = x + h
    if cross is not None:
        h, _ = attn.attention_decode(cross_params["attn"], cfg,
                                     apply_norm(cross_params["ln"], x),
                                     None, kv_src=cross)
        x = x + h
    hin = apply_norm(block["ln2"], x)
    if cfg.is_moe:
        h, _ = moe_lib.apply_moe(block["moe"], cfg, hin)
    else:
        h = apply_mlp(block["mlp"], hin, cfg.act)
    return x + h, new_cache


def decode_step(params, cfg: ModelConfig, token, cache
                ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One-token decode. token: (B, 1) int32. Returns (logits (B,1,V), cache)."""
    x = embed_tokens(params["embed"], token).astype(dtype_of(cfg))
    unit = cfg.block_unit
    new_cache = dict(cache)
    if unit == ("attn",):
        if cfg.is_encoder_decoder:
            enc = cache["encoder_out"]
            caches = cache["attn"]
            outs = []
            for i in range(cfg.n_layers):
                layer = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
                cross = jax.tree_util.tree_map(lambda a: a[i], params["cross"])
                lc = jax.tree_util.tree_map(lambda a: a[i], caches)
                x, nc = _attn_block_decode(layer, cfg, x, lc, cross=enc,
                                           cross_params=cross)
                outs.append(nc)
            new_cache["attn"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *outs)
        else:
            def body(x, inputs):
                layer, lc = inputs
                x, nc = _attn_block_decode(layer, cfg, x, lc)
                return x, nc

            x, stacked = jax.lax.scan(body, x, (params["layers"], cache["attn"]))
            new_cache["attn"] = stacked
    elif unit == ("rwkv",):
        def body(x, inputs):
            layer, lc = inputs
            h, frag = rwkv_lib.rwkv_tmix_decode(
                layer["tmix"], cfg, apply_norm(layer["ln1"], x), lc)
            x = x + h
            xn = apply_norm(layer["ln2"], x)
            x = x + rwkv_lib.rwkv_cmix(layer["cmix"], xn, lc["cmix_prev"])
            nc = {"state": frag["state"], "tmix_prev": frag["tmix_prev"],
                  "cmix_prev": xn}
            return x, nc

        x, stacked = jax.lax.scan(body, x, (params["layers"], cache["rwkv"]))
        new_cache["rwkv"] = stacked
    else:  # hybrid
        shared = params.get("shared_attn")

        def unit_body(carry, inputs):
            x = carry
            unit_params, unit_cache, sa_cache = inputs

            def mbody(x, z):
                layer, lc = z
                h, nc = ssm_lib.mamba_decode(layer["mamba"], cfg,
                                             apply_norm(layer["ln"], x), lc)
                return x + h, nc

            x, new_mc = jax.lax.scan(mbody, x, (unit_params, unit_cache))
            new_sa = sa_cache
            if shared is not None:
                x, new_sa = _attn_block_decode(shared, cfg, x, sa_cache)
            return x, (new_mc, new_sa)

        sa_caches = cache.get("shared_attn")
        x, (new_mc, new_sa) = jax.lax.scan(
            unit_body, x, (params["mamba_units"], cache["mamba"], sa_caches))
        new_cache["mamba"] = new_mc
        if sa_caches is not None:
            new_cache["shared_attn"] = new_sa
    x = apply_norm(params["ln_f"], x)
    logits = unembed(params["embed"], x).astype(jnp.float32)  # decode: tiny
    return logits, new_cache
