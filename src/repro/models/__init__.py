from repro.models.zoo import ZOO, get_local_model
