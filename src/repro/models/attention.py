"""GQA attention: training (causal / sliding-window / bidirectional), cross
attention (enc-dec), and single-token decode against a KV or ring cache.

Shapes: x (B, S, d); q (B, S, H, hd); k,v (B, S, KV, hd).

TP design note (DESIGN.md Sec. 4): KV heads are *repeated* to the full H
before the score einsum, so the head dimension shards cleanly on the "model"
mesh axis even when KV < model-axis size (a grouped (kv, g) einsum cannot
represent a 16-way shard of 8 KV heads — that was measured as a 137 GiB/device
unsharded score tensor in the first dry-run; see EXPERIMENTS.md SS Perf).
Softmax accumulates in f32. A Pallas flash path (repro.kernels) can be
enabled via ``flash=True`` for the training shapes.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import pspec
from repro.models.layers import (
    apply_rotary, dense_init, dtype_of, rms_head_norm, rotary_freqs,
)

NEG_INF = -1e30


def init_attention(rng, cfg: ModelConfig, cross: bool = False):
    d, hd = cfg.d_model, cfg.hd
    dt = dtype_of(cfg)
    ks = jax.random.split(rng, 6)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dt),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dt),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dt),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dt),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(params, cfg: ModelConfig, xq, xkv):
    b, s, _ = xq.shape
    skv = xkv.shape[1]
    hd = cfg.hd
    q = (xq @ params["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (xkv @ params["wk"]).reshape(b, skv, cfg.n_kv_heads, hd)
    v = (xkv @ params["wv"]).reshape(b, skv, cfg.n_kv_heads, hd)
    if "q_norm" in params:
        q = rms_head_norm(q, params["q_norm"])
        k = rms_head_norm(k, params["k_norm"])
    return q, k, v


def _repeat_kv(k, n_heads: int):
    """(B, S, KV, hd) -> (B, S, H, hd) by repeating each KV head G times."""
    g = n_heads // k.shape[2]
    if g == 1:
        return k
    return jnp.repeat(k, g, axis=2)


def _constrain_heads(x, batch: int):
    """(B, S, H, hd): shard batch on data axes, heads on model."""
    return pspec.constrain(
        x, P(pspec.batch_axis(batch), None, pspec.model_axis(x.shape[2]), None))


def attention_train(params, cfg: ModelConfig, x, positions,
                    causal: bool = True, window: Optional[int] = None,
                    kv_src: Optional[jnp.ndarray] = None,
                    flash: bool = False):
    """Full-sequence attention. kv_src != None -> cross attention (no mask).
    Returns (B, S, d)."""
    b = x.shape[0]
    xkv = kv_src if kv_src is not None else x
    q, k, v = _project_qkv(params, cfg, x, xkv)
    if kv_src is None:  # self-attention: rotary on q and k
        sin, cos = rotary_freqs(cfg, positions)
        q = apply_rotary(q, sin, cos)
        k = apply_rotary(k, sin, cos)
    if flash and kv_src is None:
        from repro.kernels.ops import flash_attention
        out = flash_attention(q, k, v, causal=causal, window=window)
        out = out.reshape(out.shape[0], out.shape[1], -1)
        return out @ params["wo"]
    k = _repeat_kv(k, cfg.n_heads)
    v = _repeat_kv(v, cfg.n_heads)
    q = _constrain_heads(q, b)
    k = _constrain_heads(k, b)
    v = _constrain_heads(v, b)
    s_len = q.shape[1]
    self_attn = kv_src is None
    chunk = cfg.attn_chunk
    if chunk and s_len > chunk and s_len % chunk == 0 and self_attn:
        out = _chunked_attention(q, k, v, positions, causal=causal and
                                 self_attn, window=window if self_attn else
                                 None, chunk=chunk, batch=b,
                                 heads=cfg.n_heads)
    else:
        scores = jnp.einsum("bshd,bthd->bhst", q, k,
                            preferred_element_type=jnp.float32) \
            * (cfg.hd ** -0.5)
        scores = pspec.constrain(
            scores, P(pspec.batch_axis(b), pspec.model_axis(cfg.n_heads),
                      None, None))
        if self_attn and (causal or window is not None):
            qpos = positions[:, None] if positions.ndim == 1 else positions
            kpos = qpos
            mask = None
            if causal:
                mask = qpos[..., :, None] >= kpos[..., None, :]
            if window is not None:
                wmask = qpos[..., :, None] - kpos[..., None, :] < window
                mask = wmask if mask is None else (mask & wmask)
            scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v)
    out = out.reshape(b, out.shape[1], -1)
    return out @ params["wo"]


def _chunked_attention(q, k, v, positions, *, causal, window, chunk, batch,
                       heads):
    """Online-softmax attention scanning KV chunks — the flash algorithm in
    pure JAX so GSPMD can partition it (the Pallas kernel is the TPU-native
    twin; see repro.kernels.flash_attention). Bounds the score temporaries to
    (B, H, S, chunk) instead of (B, H, S, S).

    q: (B, S, H, hd); k,v: (B, T, H, hd) (heads already repeated)."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    nc = t // chunk
    scale = hd ** -0.5
    qf = q   # bf16 operands; f32 accumulation via preferred_element_type
    qpos = positions if positions.ndim == 2 else positions[None]
    kc = jnp.moveaxis(k.reshape(b, nc, chunk, h, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nc, chunk, h, hd), 1, 0)
    kposc = jnp.moveaxis(qpos.reshape(b, nc, chunk), 1, 0)
    bax = pspec.batch_axis(batch)
    hax = pspec.model_axis(heads)

    def step(carry, inputs):
        m, l, acc = carry
        kb, vb, kp = inputs                           # (B,C,H,hd), (B,C)
        srs = jnp.einsum("bshd,bchd->bhsc", qf, kb,
                         preferred_element_type=jnp.float32) * scale
        srs = pspec.constrain(srs, P(bax, hax, None, None))
        mask = None
        if causal:
            mask = qpos[:, None, :, None] >= kp[:, None, None, :]
        if window is not None:
            wm = qpos[:, None, :, None] - kp[:, None, None, :] < window
            mask = wm if mask is None else (mask & wm)
        if mask is not None:
            srs = jnp.where(mask, srs, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(srs, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(srs - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhsc,bchd->bhsd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    acc0 = pspec.constrain(jnp.zeros((b, h, s, hd), jnp.float32),
                           P(bax, hax, None, None))
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kc, vc, kposc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)   # (B,S,H,hd)


# ----------------------------------------------------------------- caches
def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """Full KV cache (decode_32k) or ring cache (window decode)."""
    size = min(max_len, cfg.window) if cfg.window else max_len
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.hd), dtype),
        "pos": jnp.full((size,), -1, jnp.int32),   # true positions (ring aware)
        "idx": jnp.zeros((), jnp.int32),           # next true position
    }


def attention_decode(params, cfg: ModelConfig, x, cache,
                     kv_src: Optional[jnp.ndarray] = None):
    """One-token decode. x: (B, 1, d). Returns (out (B,1,d), new_cache).

    Cross attention (kv_src given) attends to precomputed encoder states and
    leaves the cache untouched.
    """
    b = x.shape[0]
    if kv_src is not None:
        q, k, v = _project_qkv(params, cfg, x, kv_src)
        k = _repeat_kv(k, cfg.n_heads)
        v = _repeat_kv(v, cfg.n_heads)
        scores = jnp.einsum("bshd,bthd->bhst", q, k,
                            preferred_element_type=jnp.float32) \
            * (cfg.hd ** -0.5)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v)
        return out.reshape(b, 1, -1) @ params["wo"], cache

    idx = cache["idx"]
    pos = jnp.full((b, 1), idx, jnp.int32)
    q, k_new, v_new = _project_qkv(params, cfg, x, x)
    sin, cos = rotary_freqs(cfg, pos)
    q = apply_rotary(q, sin, cos)
    k_new = apply_rotary(k_new, sin, cos)

    size = cache["k"].shape[1]
    slot = idx % size if cfg.window else idx   # ring buffer when windowed
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
    pos_arr = jax.lax.dynamic_update_slice(
        cache["pos"], idx[None].astype(jnp.int32), (slot,))

    # decode layout: the cache is hd-sharded on "model" (the only way the
    # 275 GB decode_32k caches fit). q must match, or XLA gathers the WHOLE
    # cache in f32 per layer to reconcile the H-sharded q with the hd-sharded
    # k (measured: 64 GiB/chip/step all-gather). hd-sharded q makes the score
    # einsum a local partial-sum + a ~34 MB/layer all-reduce.
    bax = pspec.batch_axis(b)
    hd_ax = pspec.model_axis(cfg.hd)
    qspec = P(bax, None, None, hd_ax)
    q = pspec.constrain(q, qspec)
    k_full = pspec.constrain(_repeat_kv(k_cache, cfg.n_heads), qspec)
    v_full = pspec.constrain(_repeat_kv(v_cache, cfg.n_heads), qspec)
    scores = jnp.einsum("bshd,bthd->bhst", q, k_full,
                        preferred_element_type=jnp.float32) * (cfg.hd ** -0.5)
    scores = pspec.constrain(scores, P(bax, None, None, None))
    valid = (pos_arr >= 0) & (pos_arr <= idx)
    if cfg.window is not None:
        valid = valid & (pos_arr > idx - cfg.window)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs.astype(v_full.dtype), v_full)
    out = out.reshape(b, 1, -1) @ params["wo"]
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos_arr, "idx": idx + 1}
    return out, new_cache
