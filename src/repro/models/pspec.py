"""Activation-sharding hints (MaxText-style with_sharding_constraint policy).

Model code calls ``constrain(x, spec)`` at layer boundaries; the launcher
installs the active mesh via ``set_mesh``. With no mesh installed (CPU unit
tests) every call is a no-op, so the model code stays mesh-agnostic.

This module is also the perf-iteration surface: SS Perf experiments flip
specs here (e.g. sequence-sharded long-context activations) without touching
model code.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH = None


def set_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


def data_axes() -> Optional[Tuple[str, ...]]:
    if _MESH is None:
        return None
    return tuple(a for a in ("pod", "data") if a in _MESH.axis_names)


def batch_axis(b: int):
    """The batch sharding axes, or None when b doesn't divide."""
    if _MESH is None:
        return None
    dp = data_axes()
    size = 1
    for a in dp:
        size *= _MESH.shape[a]
    return dp if b % size == 0 else None


def model_axis(dim: int):
    """"model" when dim divides the model-axis size, else None."""
    if _MESH is None:
        return None
    return "model" if dim % _MESH.shape["model"] == 0 else None


def constrain(x, spec: P):
    if _MESH is None:
        return x
    if all(e is None for e in spec):
        return x   # no-op (also keeps shard_map Manual regions clean)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))
