"""Local model zoo for GAL organizations (paper Sec. 4.1 "model autonomy").

Each organization may privately choose any model class F_m. The paper uses
Linear / Gradient Boosting / SVM / CNN / LSTM; offline we provide:

  * Linear          — closed-form ridge (ell_2) or Adam fit (other ell_q)
  * MLP             — feature extractor + head (supports Interm fusion + DMS)
  * StumpBoost      — gradient-boosted decision stumps (the paper's "GB")
  * KernelRidge     — RBF kernel machine (stand-in for the paper's "SVM";
                      same model-autonomy point, closed-form, no libsvm offline)
  * ConvNet         — the paper's Table-8 CNN family (scaled) for patch images
  * GRUNet          — recurrent net for the MIMIC-like time-series case study

Interface (duck-typed, see Organization):
  init(rng, x_example, k_out) -> params
  fit(rng, x, r, local_loss)  -> params          (fresh fit to pseudo-residuals)
  apply(params, x)            -> (N, K)
Optionally for Interm fusion / DMS:
  features(params, x) -> (N, H), feature_dim(x_example), init_head, apply_head

``scan_safe = True`` declares that ``fit``/``apply`` are pure functions of
their jnp inputs (no Python-level data-dependent control flow or host
callbacks), so the fused GAL engine may jit them and vmap one model instance
over org-stacked slices. External duck-typed models default to NOT scan-safe
and route through the Python reference engine.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Sequence, Callable

import jax
import jax.numpy as jnp

from repro.core.losses import lq_loss
from repro.optim.optimizers import adam, apply_updates
from repro.utils.registry import Registry

ZOO: Registry = Registry("local model")


def _fit_adam(rng, params, loss_of_params, epochs: int, lr: float,
              axis_name=None):
    # axis_name: mesh axis the training rows are sharded over (the GAL
    # engine's "data" axis). loss_of_params is then the LOCAL shard's mean
    # loss; averaging the per-shard gradients over equal shards recovers
    # the global full-batch gradient, so the Adam trajectory is the
    # single-shard one up to fp summation order.
    opt = adam(lr)
    state = opt.init(params)

    @jax.jit
    def step(carry, _):
        params, state = carry
        grads = jax.grad(loss_of_params)(params)
        if axis_name is not None:
            shards = jax.lax.psum(1, axis_name)
            grads = jax.tree_util.tree_map(
                lambda g: jax.lax.psum(g, axis_name) / shards, grads)
        upd, state = opt.update(grads, state, params)
        return (apply_updates(params, upd), state), None

    (params, _), _ = jax.lax.scan(step, (params, state), None, length=epochs)
    return params


def _dense_init(rng, d_in, d_out, scale=None):
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d_in)
    kw, _ = jax.random.split(rng)
    return {"w": jax.random.normal(kw, (d_in, d_out)) * scale,
            "b": jnp.zeros((d_out,))}


def _dense(params, x):
    return x @ params["w"] + params["b"]


@ZOO.register("linear")
@dataclass(frozen=True)
class Linear:
    scan_safe = True  # pure-jnp fit/apply: safe under jit/vmap
    data_parallel = True  # fit accepts data_axis (rows sharded on a mesh)
    ridge: float = 1e-3
    epochs: int = 100          # used only for non-ell_2 local losses
    lr: float = 1e-2

    def pad_invariant(self, q: float) -> bool:
        # closed-form ridge decouples zero columns exactly; the q!=2 Adam
        # path inits params at the padded width, changing the random draws
        return q == 2.0

    def init(self, rng, x_example, k_out):
        return _dense_init(rng, x_example.shape[-1], k_out)

    def apply(self, params, x):
        return _dense(params, x)

    def fit(self, rng, x, r, local_loss, data_axis=None):
        # the closed ridge form is ONLY the ell_2 solution; a custom loss
        # without a q exponent takes the generic Adam path (it is
        # differentiated directly, so any traceable loss compiles)
        q = getattr(local_loss, "q", None)
        if q == 2.0:
            # closed-form ridge regression of residuals; with the rows
            # sharded over ``data_axis``, the gram matrix and rhs are
            # sums over rows, so psumming the local partial sums yields
            # the exact global normal equations
            n, d = x.shape
            xb = jnp.concatenate([x, jnp.ones((n, 1))], axis=1)
            gram = xb.T @ xb
            rhs = xb.T @ r
            if data_axis is not None:
                gram = jax.lax.psum(gram, data_axis)
                rhs = jax.lax.psum(rhs, data_axis)
            sol = jnp.linalg.solve(gram + self.ridge * jnp.eye(d + 1), rhs)
            return {"w": sol[:-1], "b": sol[-1]}
        params = self.init(rng, x, r.shape[-1])
        return _fit_adam(
            rng, params, lambda p: local_loss(r, _dense(p, x)),
            self.epochs, self.lr, axis_name=data_axis,
        )


@ZOO.register("mlp")
@dataclass(frozen=True)
class MLP:
    scan_safe = True  # pure-jnp fit/apply: safe under jit/vmap
    data_parallel = True  # fit accepts data_axis (rows sharded on a mesh)
    hidden: Sequence[int] = (64, 64)
    epochs: int = 200
    lr: float = 1e-2

    def feature_dim(self, x_example):
        return self.hidden[-1]

    def init(self, rng, x_example, k_out):
        dims = [x_example.shape[-1], *self.hidden]
        keys = jax.random.split(rng, len(dims))
        layers = [_dense_init(keys[i], dims[i], dims[i + 1])
                  for i in range(len(dims) - 1)]
        head = _dense_init(keys[-1], dims[-1], k_out)
        return {"layers": layers, "head": head}

    def features(self, params, x):
        h = x
        for lyr in params["layers"]:
            h = jax.nn.relu(_dense(lyr, h))
        return h

    def init_head(self, rng, k_out):
        return _dense_init(rng, self.hidden[-1], k_out)

    def apply_head(self, head, h):
        return _dense(head, h)

    def apply(self, params, x):
        return _dense(params["head"], self.features(params, x))

    def fit(self, rng, x, r, local_loss, data_axis=None):
        params = self.init(rng, x, r.shape[-1])
        return _fit_adam(
            rng, params, lambda p: local_loss(r, self.apply(p, x)),
            self.epochs, self.lr, axis_name=data_axis,
        )


@ZOO.register("stump_boost")
@dataclass(frozen=True)
class StumpBoost:
    """Gradient-boosted decision stumps — the paper's "GB" local model.

    Vectorized greedy stump selection over a per-feature quantile grid of
    candidate thresholds; each stump fits the current residual-of-residual
    with per-leaf means, shrunk by ``shrinkage``.
    """
    scan_safe = True  # pure-jnp fit/apply: safe under jit/vmap
    pad_invariant = True  # zero columns have zero split gain
    n_stumps: int = 50
    n_thresholds: int = 16
    shrinkage: float = 0.3

    def init(self, rng, x_example, k_out):
        d = x_example.shape[-1]
        t = self.n_thresholds
        return {
            "thresholds": jnp.zeros((d, t)),
            "feat": jnp.zeros((self.n_stumps,), jnp.int32),
            "thr": jnp.zeros((self.n_stumps,)),
            "left": jnp.zeros((self.n_stumps, k_out)),
            "right": jnp.zeros((self.n_stumps, k_out)),
            "base": jnp.zeros((k_out,)),
        }

    def fit(self, rng, x, r, local_loss):
        del rng, local_loss  # stumps always fit ell_2 internally (classic GB)
        n, d = x.shape
        k = r.shape[-1]
        qs = jnp.linspace(0.05, 0.95, self.n_thresholds)
        thresholds = jnp.quantile(x, qs, axis=0).T            # (d, T)
        base = jnp.mean(r, axis=0)
        resid0 = r - base

        masks = x[:, :, None] <= thresholds[None, :, :]        # (n, d, T)
        masks_f = masks.astype(jnp.float32)
        n_left = jnp.sum(masks_f, axis=0)                      # (d, T)
        n_right = n - n_left

        def one_stump(resid, _):
            sum_left = jnp.einsum("ndt,nk->dtk", masks_f, resid)
            sum_all = jnp.sum(resid, axis=0)                   # (k,)
            sum_right = sum_all[None, None, :] - sum_left
            mean_l = sum_left / jnp.maximum(n_left, 1.0)[..., None]
            mean_r = sum_right / jnp.maximum(n_right, 1.0)[..., None]
            # SSE reduction = sum_l . mean_l + sum_r . mean_r (up to const)
            gain = (jnp.sum(sum_left * mean_l, axis=-1)
                    + jnp.sum(sum_right * mean_r, axis=-1))    # (d, T)
            idx = jnp.argmax(gain)
            fi, ti = idx // self.n_thresholds, idx % self.n_thresholds
            thr = thresholds[fi, ti]
            lval = self.shrinkage * mean_l[fi, ti]
            rval = self.shrinkage * mean_r[fi, ti]
            go_left = (x[:, fi] <= thr)[:, None]
            pred = jnp.where(go_left, lval[None, :], rval[None, :])
            return resid - pred, (fi.astype(jnp.int32), thr, lval, rval)

        _, (feat, thr, left, right) = jax.lax.scan(
            one_stump, resid0, None, length=self.n_stumps
        )
        return {"thresholds": thresholds, "feat": feat, "thr": thr,
                "left": left, "right": right, "base": base}

    def apply(self, params, x):
        def one(carry, stump):
            fi, thr, lval, rval = stump
            go_left = (x[:, fi] <= thr)[:, None]
            return carry + jnp.where(go_left, lval[None, :], rval[None, :]), None

        init = jnp.broadcast_to(params["base"], (x.shape[0], params["base"].shape[0]))
        out, _ = jax.lax.scan(
            one, init,
            (params["feat"], params["thr"], params["left"], params["right"]),
        )
        return out


@ZOO.register("kernel_ridge")
@dataclass(frozen=True)
class KernelRidge:
    """RBF kernel ridge regression (the paper's "SVM" autonomy stand-in)."""
    scan_safe = True  # pure-jnp fit/apply: safe under jit/vmap
    pad_invariant = True  # zero columns add nothing to RBF distances
    gamma: float = 0.5
    reg: float = 1e-2

    def init(self, rng, x_example, k_out):
        return {"x_train": jnp.zeros((1, x_example.shape[-1])),
                "alpha": jnp.zeros((1, k_out))}

    def _kernel(self, a, b):
        sq = (jnp.sum(a * a, -1)[:, None] + jnp.sum(b * b, -1)[None, :]
              - 2.0 * a @ b.T)
        return jnp.exp(-self.gamma * jnp.maximum(sq, 0.0))

    def fit(self, rng, x, r, local_loss):
        del rng, local_loss
        k = self._kernel(x, x)
        alpha = jnp.linalg.solve(k + self.reg * jnp.eye(x.shape[0]), r)
        return {"x_train": x, "alpha": alpha}

    def apply(self, params, x):
        return self._kernel(x, params["x_train"]) @ params["alpha"]


def _conv(params, x, stride=1):
    # x: (N, H, W, C)
    return jax.lax.conv_general_dilated(
        x, params["w"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + params["b"]


def _conv_init(rng, cin, cout, ksize=3):
    scale = 1.0 / jnp.sqrt(ksize * ksize * cin)
    return {"w": jax.random.normal(rng, (ksize, ksize, cin, cout)) * scale,
            "b": jnp.zeros((cout,))}


@ZOO.register("convnet")
@dataclass(frozen=True)
class ConvNet:
    """Paper Table-8 CNN (conv+pool x4, GAP, linear), width-scaled for CPU."""
    scan_safe = True  # pure-jnp fit/apply: safe under jit/vmap
    data_parallel = True  # fit accepts data_axis (rows sharded on a mesh)
    widths: Sequence[int] = (16, 32, 64, 64)
    epochs: int = 60
    lr: float = 1e-3
    batch: int = 0  # 0 = full batch

    def feature_dim(self, x_example):
        return self.widths[-1]

    def init(self, rng, x_example, k_out):
        cin = x_example.shape[-1]
        keys = jax.random.split(rng, len(self.widths) + 1)
        convs = []
        for i, w in enumerate(self.widths):
            convs.append(_conv_init(keys[i], cin, w))
            cin = w
        head = _dense_init(keys[-1], self.widths[-1], k_out)
        return {"convs": convs, "head": head}

    def features(self, params, x):
        h = x
        for conv in params["convs"]:
            h = jax.nn.relu(_conv(conv, h))
            if h.shape[1] > 1:
                h = jax.lax.reduce_window(
                    h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
                )
        return jnp.mean(h, axis=(1, 2))  # global average pool

    def init_head(self, rng, k_out):
        return _dense_init(rng, self.widths[-1], k_out)

    def apply_head(self, head, h):
        return _dense(head, h)

    def apply(self, params, x):
        return _dense(params["head"], self.features(params, x))

    def fit(self, rng, x, r, local_loss, data_axis=None):
        params = self.init(rng, x, r.shape[-1])
        return _fit_adam(
            rng, params, lambda p: local_loss(r, self.apply(p, x)),
            self.epochs, self.lr, axis_name=data_axis,
        )


@ZOO.register("grunet")
@dataclass(frozen=True)
class GRUNet:
    """GRU over (N, T, D) series + linear head (MIMIC-like case study)."""
    scan_safe = True  # pure-jnp fit/apply: safe under jit/vmap
    data_parallel = True  # fit accepts data_axis (rows sharded on a mesh)
    hidden_size: int = 32
    epochs: int = 120
    lr: float = 3e-3

    def feature_dim(self, x_example):
        return self.hidden_size

    def init(self, rng, x_example, k_out):
        d = x_example.shape[-1]
        h = self.hidden_size
        k1, k2, k3 = jax.random.split(rng, 3)
        return {
            "wx": jax.random.normal(k1, (d, 3 * h)) / jnp.sqrt(d),
            "wh": jax.random.normal(k2, (h, 3 * h)) / jnp.sqrt(h),
            "b": jnp.zeros((3 * h,)),
            "head": _dense_init(k3, h, k_out),
        }

    def features(self, params, x):
        h0 = jnp.zeros((x.shape[0], self.hidden_size))

        def cell(h, xt):
            gates = xt @ params["wx"] + h @ params["wh"] + params["b"]
            z, r_, n = jnp.split(gates, 3, axis=-1)
            z, r_ = jax.nn.sigmoid(z), jax.nn.sigmoid(r_)
            n = jnp.tanh(xt @ params["wx"][:, -self.hidden_size:]
                         + r_ * (h @ params["wh"][:, -self.hidden_size:]))
            return (1 - z) * n + z * h, None

        h, _ = jax.lax.scan(cell, h0, jnp.swapaxes(x, 0, 1))
        return h

    def init_head(self, rng, k_out):
        return _dense_init(rng, self.hidden_size, k_out)

    def apply_head(self, head, h):
        return _dense(head, h)

    def apply(self, params, x):
        return _dense(params["head"], self.features(params, x))

    def fit(self, rng, x, r, local_loss, data_axis=None):
        params = self.init(rng, x, r.shape[-1])
        return _fit_adam(
            rng, params, lambda p: local_loss(r, self.apply(p, x)),
            self.epochs, self.lr, axis_name=data_axis,
        )


def get_local_model(name: str, **kwargs):
    return ZOO.get(name)(**kwargs)
