"""Mamba2 (SSD) block — chunked block-decomposition for training, O(1)-state
recurrent step for decode (zamba2 hybrid + long-context shapes).

Per head h with state (N x P):   (P = channels/head, N = ssm_state)
    h_t = exp(dt_t * a) * h_{t-1} + dt_t * B_t (N) outer x_t (P)
    y_t = C_t . h_t + D * x_t
Training uses the SSD chunk algorithm: quadratic within chunks of length
``chunk``; a lax.scan carries the inter-chunk state. TPU-adaptation note
(DESIGN.md Sec. 5): the chunk dimension is the MXU tile — all intra-chunk work
is batched einsums; only the tiny (N x P) state crosses chunks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import pspec
from repro.models.layers import dense_init, dtype_of

CHUNK = 256


def ssm_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    heads = cfg.ssm_heads or max(d_in // 64, 1)
    p = d_in // heads
    return d_in, heads, p, cfg.ssm_state


def init_mamba(rng, cfg: ModelConfig):
    d = cfg.d_model
    d_in, h, p, n = ssm_dims(cfg)
    dt = dtype_of(cfg)
    ks = jax.random.split(rng, 7)
    conv_ch = d_in + 2 * n  # conv over (x, B, C) as in mamba2
    # separate projections (not one fused in_proj): keeps every matmul's
    # output dim cleanly shardable on the "model" mesh axis (DESIGN.md Sec. 4)
    return {
        "w_z": dense_init(ks[0], d, d_in, dt),
        "w_x": dense_init(ks[1], d, d_in, dt),
        "w_B": dense_init(ks[2], d, n, dt),
        "w_C": dense_init(ks[3], d, n, dt),
        "w_dt": dense_init(ks[4], d, h, dt),
        "conv_w": (jax.random.normal(ks[5], (cfg.conv_width, conv_ch),
                                     jnp.float32) * 0.2).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "a_log": jnp.zeros((h,), jnp.float32),       # a = -exp(a_log)
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_proj": dense_init(ks[6], d_in, d, dt),
        "norm_z": jnp.ones((d_in,), jnp.float32),
    }


def _split_proj(cfg, params, xin):
    z = xin @ params["w_z"]
    x = xin @ params["w_x"]
    b = xin @ params["w_B"]
    c = xin @ params["w_C"]
    dt_raw = xin @ params["w_dt"]
    return z, x, b, c, dt_raw


def _causal_conv(params, u, state=None):
    """u: (B, S, C). Short causal conv, optionally seeded with carry state
    (B, W-1, C) for decode. Returns (out, new_state)."""
    w = params["conv_w"].astype(u.dtype)              # (W, C)
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], width - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)
    out = sum(full[:, i:i + u.shape[1], :] * w[i] for i in range(width))
    out = jax.nn.silu(out + params["conv_b"].astype(u.dtype))
    new_state = full[:, -(width - 1):, :]
    return out, new_state


def mamba_train(params, cfg: ModelConfig, xin):
    """xin: (B, S, d) -> (B, S, d). S must be a multiple of CHUNK or < CHUNK."""
    bsz, s, _ = xin.shape
    d_in, h, p, n = ssm_dims(cfg)
    chunk = min(CHUNK, s)
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nc = s // chunk

    z, x, b, c, dt_raw = _split_proj(cfg, params, xin)
    bax = pspec.batch_axis(bsz)
    x = pspec.constrain(x, P(bax, None, pspec.model_axis(d_in)))
    z = pspec.constrain(z, P(bax, None, pspec.model_axis(d_in)))
    conv_in = jnp.concatenate([x, b, c], axis=-1)
    conv_out, _ = _causal_conv(params, conv_in)
    x, b, c = (conv_out[..., :d_in], conv_out[..., d_in:d_in + n],
               conv_out[..., d_in + n:])

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])                      # (B,S,H)
    a = -jnp.exp(params["a_log"])                                  # (H,)
    log_decay = dt * a                                             # (B,S,H) <= 0

    # reshape to chunks
    hax = pspec.model_axis(h)
    xc = x.reshape(bsz, nc, chunk, h, p).astype(jnp.float32)
    xc = pspec.constrain(xc, P(bax, None, None, hax, None))
    bc = b.reshape(bsz, nc, chunk, n).astype(jnp.float32)
    cc = c.reshape(bsz, nc, chunk, n).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, chunk, h)
    ld = log_decay.reshape(bsz, nc, chunk, h)
    lcum = jnp.cumsum(ld, axis=2)                                  # (B,nc,L,H)

    # ---- intra-chunk (quadratic in chunk): mask exp(lcum_t - lcum_s) causal
    rel = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]          # (B,nc,t,s,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
    decay_mat = jnp.where(tri[None, None, :, :, None], jnp.exp(rel), 0.0)
    cb = jnp.einsum("zltn,zlsn->zlts", cc, bc)                     # (B,nc,t,s)
    gates = cb[..., None] * decay_mat                              # (B,nc,t,s,H)
    gates = pspec.constrain(gates, P(bax, None, None, None, hax))
    y_intra = jnp.einsum("zltsh,zlsh,zlshp->zlthp", gates, dtc, xc)
    y_intra = pspec.constrain(y_intra, P(bax, None, None, hax, None))

    # ---- chunk states and inter-chunk scan
    tail = lcum[:, :, -1:, :] - lcum                               # (B,nc,L,H)
    state_c = jnp.einsum("zlsh,zlsh,zlsn,zlshp->zlhnp",
                         jnp.exp(tail), dtc, bc, xc)               # per-chunk
    total = jnp.exp(lcum[:, :, -1, :])                             # (B,nc,H)

    def carry_fn(hstate, inputs):
        s_c, tot = inputs
        y_state = hstate                                           # (B,H,N,P)
        new = y_state * tot[:, :, None, None] + s_c
        return new, y_state

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    _, h_prev = jax.lax.scan(
        carry_fn, h0,
        (jnp.moveaxis(state_c, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)                            # (B,nc,H,N,P)
    y_inter = jnp.einsum("zltn,zlth,zlhnp->zlthp",
                         cc, jnp.exp(lcum), h_prev)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    y = y + params["d_skip"][None, None, :, None] * \
        x.reshape(bsz, s, h, p).astype(jnp.float32)
    y = y.reshape(bsz, s, d_in).astype(xin.dtype)
    # gated RMSNorm (mamba2 norm before out_proj)
    zf = jax.nn.silu(z.astype(jnp.float32))
    yf = y.astype(jnp.float32) * zf
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(ms + 1e-6) * params["norm_z"]
    return (yf.astype(xin.dtype)) @ params["out_proj"]


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    d_in, h, p, n = ssm_dims(cfg)
    conv_ch = d_in + 2 * n
    return {
        "h": jnp.zeros((batch, h, n, p), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
    }


def mamba_decode(params, cfg: ModelConfig, xin, cache):
    """xin: (B, 1, d). Returns (y (B,1,d), new_cache)."""
    bsz = xin.shape[0]
    d_in, h, p, n = ssm_dims(cfg)
    z, x, b, c, dt_raw = _split_proj(cfg, params, xin)
    conv_in = jnp.concatenate([x, b, c], axis=-1)
    conv_out, conv_state = _causal_conv(params, conv_in, cache["conv"])
    x, b, c = (conv_out[..., :d_in], conv_out[..., d_in:d_in + n],
               conv_out[..., d_in + n:])

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt * a)                                        # (B,H)
    xf = x[:, 0].reshape(bsz, h, p).astype(jnp.float32)
    bf = b[:, 0].astype(jnp.float32)                               # (B,N)
    cf = c[:, 0].astype(jnp.float32)
    hstate = cache["h"] * decay[:, :, None, None] + jnp.einsum(
        "zh,zn,zhp->zhnp", dt, bf, xf
    )
    y = jnp.einsum("zn,zhnp->zhp", cf, hstate)
    y = y + params["d_skip"][None, :, None] * xf
    y = y.reshape(bsz, 1, d_in)
    zf = jax.nn.silu(z.astype(jnp.float32))
    yf = y.astype(jnp.float32) * zf
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(ms + 1e-6) * params["norm_z"]
    out = yf.astype(xin.dtype) @ params["out_proj"]
    return out, {"h": hstate, "conv": conv_state}
