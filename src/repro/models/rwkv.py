"""RWKV6 "Finch" block (arXiv:2404.05892): data-dependent decay linear
attention with constant-size state — the assigned attention-free arch.

Time-mix (per head, k/v dims = head size):
    y_t = r_t^T (S_t + diag(u) k_t v_t^T)
    S_{t+1} = diag(w_t) S_t + k_t v_t^T,   w_t = exp(-exp(w0 + lora_w(x)))
Data dependence: token-shift mixing coefficients and the decay w_t are
low-rank functions of the input (the Finch contribution).

Training runs a lax.scan over time carrying S (B, H, K, V); decode is a single
state update. Channel-mix is the RWKV squared-relu FFN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import pspec
from repro.models.layers import dense_init, dtype_of

LORA_R = 32
CHUNK = 32    # factorized-WKV chunk (f32-safe with decay floor)
_MIX = ("r", "k", "v", "w", "g")


def rwkv_dims(cfg: ModelConfig):
    hd = 64 if cfg.d_model % 64 == 0 else cfg.d_model // cfg.n_heads
    heads = cfg.d_model // hd
    return heads, hd


def init_rwkv_tmix(rng, cfg: ModelConfig):
    d = cfg.d_model
    h, hd = rwkv_dims(cfg)
    dt = dtype_of(cfg)
    ks = jax.random.split(rng, 10)
    p = {
        "mu": (jax.random.uniform(ks[0], (len(_MIX), d), jnp.float32)).astype(dt),
        "mix_lora_a": dense_init(ks[1], d, LORA_R * len(_MIX), dt),
        "mix_lora_b": (jax.random.normal(ks[2], (len(_MIX), LORA_R, d),
                                         jnp.float32) * 0.01).astype(dt),
        "wr": dense_init(ks[3], d, d, dt),
        "wk": dense_init(ks[4], d, d, dt),
        "wv": dense_init(ks[5], d, d, dt),
        "wg": dense_init(ks[6], d, d, dt),
        "wo": dense_init(ks[7], d, d, dt),
        "w0": jnp.full((d,), -1.0, jnp.float32),       # base decay
        "w_lora_a": dense_init(ks[8], d, LORA_R, dt),
        "w_lora_b": (jax.random.normal(ks[9], (LORA_R, d), jnp.float32)
                     * 0.01).astype(dt),
        "u": jnp.zeros((d,), jnp.float32),             # current-token bonus
        "ln_scale": jnp.ones((d,), jnp.float32),       # per-head group norm
    }
    return p


def _token_shift(params, x, x_prev):
    """Finch data-dependent token shift. x, x_prev: (B, S, d).
    Returns dict name -> mixed input (B, S, d)."""
    delta = x_prev - x
    lora = jnp.tanh(x @ params["mix_lora_a"])            # (B,S,R*5)
    lora = lora.reshape(*x.shape[:-1], len(_MIX), LORA_R)
    dyn = jnp.einsum("bsmr,mrd->bsmd", lora, params["mix_lora_b"])
    mix = jax.nn.sigmoid(params["mu"][None, None] + dyn)  # (B,S,5,d)
    return {name: x + delta * mix[:, :, i] for i, name in enumerate(_MIX)}


LOG_DECAY_FLOOR = -2.0   # per-step log-decay clamp (f32 range safety in the
                         # factorized chunked WKV; see rwkv_tmix_train)


def _decay(params, xw):
    """w_t in (0,1): exp(clip(-exp(w0 + lora), FLOOR, 0)).
    xw: (B,S,d) -> (B,S,d) f32. The floor keeps exp(-cumsum) within f32 range
    for the chunked factorization (chunk 32 -> max exponent 64)."""
    lora = jnp.tanh(xw @ params["w_lora_a"]) @ params["w_lora_b"]
    ld = jnp.clip(-jnp.exp(params["w0"] + lora.astype(jnp.float32)),
                  LOG_DECAY_FLOOR, 0.0)
    return jnp.exp(ld)


def _group_norm(x, scale, heads, eps=1e-6):
    b, s, d = x.shape
    xg = x.reshape(b, s, heads, d // heads)
    mu = jnp.mean(xg, axis=-1, keepdims=True, dtype=jnp.float32)
    var = jnp.mean(jnp.square(xg), axis=-1, keepdims=True,
                   dtype=jnp.float32) - jnp.square(mu)
    inv = jax.lax.rsqrt(var + eps)
    out = (xg - mu.astype(x.dtype)) * inv.astype(x.dtype)
    return out.reshape(b, s, d) * scale.astype(x.dtype)


def rwkv_tmix_train(params, cfg: ModelConfig, x, x_prev_last=None):
    """x: (B, S, d) -> (B, S, d). x_prev_last: carry of last token (B,1,d)."""
    b, s, d = x.shape
    h, hd = rwkv_dims(cfg)
    if x_prev_last is None:
        x_prev_last = jnp.zeros((b, 1, d), x.dtype)
    x_prev = jnp.concatenate([x_prev_last, x[:, :-1]], axis=1)
    mixed = _token_shift(params, x, x_prev)
    r = (mixed["r"] @ params["wr"]).reshape(b, s, h, hd)
    k = (mixed["k"] @ params["wk"]).reshape(b, s, h, hd)
    v = (mixed["v"] @ params["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(mixed["g"] @ params["wg"])
    w = _decay(params, mixed["w"]).reshape(b, s, h, hd)      # f32
    u = params["u"].reshape(h, hd)

    bax = pspec.batch_axis(b)
    hax = pspec.model_axis(h)
    spec = P(bax, None, hax, None)
    rf = pspec.constrain(r.astype(jnp.float32), spec)
    kf = pspec.constrain(k.astype(jnp.float32), spec)
    vf = pspec.constrain(v.astype(jnp.float32), spec)
    w = pspec.constrain(w, spec)

    chunk = min(CHUNK, s)
    if s % chunk == 0 and s > 1:
        mesh = pspec.get_mesh()
        if mesh is not None and bax is not None and hax is not None:
            # WKV is pointwise across batch and heads: shard_map pins the
            # layout (batch on data, heads on model) and runs fully LOCAL —
            # GSPMD propagation otherwise flips the stream batch-replicated
            # (measured 8 GiB unsharded f32 buffers per device; SS Perf)
            from jax.experimental.shard_map import shard_map
            spec = P(bax, None, hax, None)
            local = shard_map(
                lambda r_, k_, v_, w_, u_: _wkv_chunked(r_, k_, v_, w_, u_,
                                                        chunk, None, None),
                mesh=mesh, in_specs=(spec, spec, spec, spec, P(hax, None)),
                out_specs=spec, check_rep=False)
            ys = local(rf, kf, vf, w, u)
        else:
            ys = _wkv_chunked(rf, kf, vf, w, u, chunk, bax, hax)  # (B,S,H,hd)
        y = ys.astype(x.dtype).reshape(b, s, d)
    else:
        def step(state, inputs):
            rt, kt, vt, wt = inputs               # (B,H,hd) each
            kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
            y = jnp.einsum("bhk,bhkv->bhv", rt,
                           state + u[None, :, :, None] * kv)
            new_state = state * wt[..., None] + kv
            return new_state, y

        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        _, ys = jax.lax.scan(
            step, s0,
            (jnp.moveaxis(rf, 1, 0), jnp.moveaxis(kf, 1, 0),
             jnp.moveaxis(vf, 1, 0), jnp.moveaxis(w, 1, 0)),
        )
        y = jnp.moveaxis(ys, 0, 1).reshape(b, s, d).astype(x.dtype)
    y = _group_norm(y, params["ln_scale"], h) * g
    return y @ params["wo"]




def _wkv_chunked(r, k, v, w, u, chunk, bax, hax):
    """Factorized chunked WKV (GLA-style block decomposition) — the TPU-native
    formulation: per-token state updates become batched einsums over chunks,
    cutting HBM state traffic by ~chunk x (a per-step scan rewrites the
    (B,H,K,V) state every token: ~TBs per training step at 4k).

    With per-channel log-decay ld and inclusive cumsum L_t within a chunk:
      y_t = r_t . (S_chunk + sum_{s<t} exp(L_{t-1}-L_s) k_s v_s + u.k_t v_t)
      S_next = exp(L_C) S_chunk + sum_s exp(L_C - L_s) k_s v_s
    Factorization: scores_ts = (r_t exp(L_{t-1})) . (k_s exp(-L_s)); the only
    positive exponent exp(-L_s) is bounded by chunk*|LOG_DECAY_FLOOR| <= 64,
    safe in f32 for chunk = 32.

    r,k,v: (B,S,H,hd) f32; w: (B,S,H,hd) decay in (0,1). Returns (B,S,H,hd).
    """
    b, s, h, hd = r.shape
    nc = s // chunk

    def c_(t):  # (B,S,H,hd) -> (B,nc,C,H,hd)
        return t.reshape(b, nc, chunk, h, hd)

    rc, kc, vc = c_(r), c_(k), c_(v)
    ld = jnp.log(jnp.maximum(c_(w), 1e-38))              # <= 0
    lcum = jnp.cumsum(ld, axis=2)                        # inclusive (B,nc,C,H,K)
    lprev = lcum - ld                                    # exclusive

    a_fac = rc * jnp.exp(lprev)                          # bounded <= |r|
    b_fac = kc * jnp.exp(-lcum)                          # bounded by chunk*floor
    scores = jnp.einsum("znthk,znshk->znhts", a_fac, b_fac)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_), k=-1)
    scores = jnp.where(tri[None, None, None], scores, 0.0)
    scores = pspec.constrain(scores, P(bax, None, hax, None, None))
    y_intra = jnp.einsum("znhts,znshv->znthv", scores, vc)
    # current-token bonus (diagonal)
    diag = jnp.einsum("znthk,znthk->znth", rc, u[None, None, None] * kc)
    y_intra = y_intra + diag[..., None] * vc

    # inter-chunk: carry state (B,H,K,V)
    tail = jnp.exp(lcum[:, :, -1:, :, :] - lcum)         # exp(L_C - L_s) <= 1
    chunk_kv = jnp.einsum("znshk,znshv->znhkv", kc * tail, vc)
    total = jnp.exp(lcum[:, :, -1])                      # (B,nc,H,K)

    def carry(state, inputs):
        ckv, tot = inputs
        prev = state
        state = state * tot[..., None] + ckv
        return state, prev

    s0 = pspec.constrain(jnp.zeros((b, h, hd, hd), jnp.float32),
                         P(bax, hax, None, None))
    _, s_prev = jax.lax.scan(
        carry, s0, (jnp.moveaxis(chunk_kv, 1, 0), jnp.moveaxis(total, 1, 0)))
    s_prev = jnp.moveaxis(s_prev, 0, 1)                  # (B,nc,H,K,V)
    y_inter = jnp.einsum("znthk,znhkv->znthv", a_fac, s_prev)
    out = (y_intra + y_inter).reshape(b, s, h, hd)
    return pspec.constrain(out, P(bax, None, hax, None))


def init_rwkv_cmix(rng, cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(rng, 4)
    return {
        "mu_k": jnp.full((d,), 0.5, dt),
        "mu_r": jnp.full((d,), 0.5, dt),
        "wk": dense_init(ks[0], d, ff, dt),
        "wv": dense_init(ks[1], ff, d, dt),
        "wr": dense_init(ks[2], d, d, dt),
    }


def rwkv_cmix(params, x, x_prev_last=None):
    b, s, d = x.shape
    if x_prev_last is None:
        x_prev_last = jnp.zeros((b, 1, d), x.dtype)
    x_prev = jnp.concatenate([x_prev_last, x[:, :-1]], axis=1)
    xk = x + (x_prev - x) * params["mu_k"]
    xr = x + (x_prev - x) * params["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ params["wk"]))
    return jax.nn.sigmoid(xr @ params["wr"]) * (k @ params["wv"])


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype):
    h, hd = rwkv_dims(cfg)
    d = cfg.d_model
    return {
        "state": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "tmix_prev": jnp.zeros((batch, 1, d), dtype),
        "cmix_prev": jnp.zeros((batch, 1, d), dtype),
    }


def rwkv_tmix_decode(params, cfg: ModelConfig, x, cache):
    """x: (B, 1, d). Returns (y, new_cache-fragment)."""
    b, _, d = x.shape
    h, hd = rwkv_dims(cfg)
    mixed = _token_shift(params, x, cache["tmix_prev"])
    r = (mixed["r"] @ params["wr"]).reshape(b, h, hd).astype(jnp.float32)
    k = (mixed["k"] @ params["wk"]).reshape(b, h, hd).astype(jnp.float32)
    v = (mixed["v"] @ params["wv"]).reshape(b, h, hd).astype(jnp.float32)
    g = jax.nn.silu(mixed["g"] @ params["wg"])
    w = _decay(params, mixed["w"]).reshape(b, h, hd)
    u = params["u"].reshape(h, hd)
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    y = jnp.einsum("bhk,bhkv->bhv", r, cache["state"] + u[None, :, :, None] * kv)
    new_state = cache["state"] * w[..., None] + kv
    y = y.reshape(b, 1, d).astype(x.dtype)
    y = _group_norm(y, params["ln_scale"], h) * g
    return y @ params["wo"], {"state": new_state, "tmix_prev": x}
