"""Mixture-of-Experts layer: top-k router + GShard-style *grouped* capacity
dispatch.

Tokens are dispatched within groups (the batch rows), so the one-hot
dispatch/combine tensors are (G, Tg, E, C) with C = cf*k*Tg/E — linear in
tokens, unlike a flat (T, E, C) which is quadratic and infeasible at the
1M-token train_4k shape. Under pjit with experts sharded on "model" and
groups on the data axes, the dispatch einsum is THE all-to-all of MoE
(visible in the dry-run HLO).

Also computes the Switch/GShard auxiliary load-balance loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import pspec
from repro.models.layers import dense_init, dtype_of

GROUP = 1024  # tokens per dispatch group


def init_moe(rng, cfg: ModelConfig):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    dt = dtype_of(cfg)
    ks = jax.random.split(rng, 4)

    def expert_stack(key, d_in, d_out):
        scale = d_in ** -0.5
        return (jax.random.normal(key, (e, d_in, d_out), jnp.float32)
                * scale).astype(dt)

    p = {"router": dense_init(ks[0], d, e, jnp.float32)}
    if cfg.act == "swiglu":
        p["w_gate"] = expert_stack(ks[1], d, ff)
        p["w_up"] = expert_stack(ks[2], d, ff)
    else:
        p["w_up"] = expert_stack(ks[1], d, ff)
    p["w_down"] = expert_stack(ks[3], ff, d)
    return p


def apply_moe(params, cfg: ModelConfig, x):
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar f32).

    Groups are GROUP-token slices of each batch row (total dispatch footprint
    is cf*k*T*Tg — linear in tokens, quadratic only in the small Tg)."""
    bsz, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_topk
    tg = min(s, GROUP)
    g = bsz * (s // tg)
    capacity = max(int(cfg.capacity_factor * k * tg / e), k)

    xt = x.reshape(g, tg, d)
    # router matmul in compute dtype; upcast only the tiny (G,Tg,E) logits —
    # an f32 xt here pushes f32 cotangents through the whole backward pass
    logits = (xt @ params["router"].astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                   # (G,Tg,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balance auxiliary loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(gate_idx, e), axis=2), axis=(0, 1)) / k
    aux = e * jnp.sum(me * ce)

    # position of each (token, choice) within its expert's per-group buffer
    expert_onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)    # (G,Tg,k,E)
    flat = expert_onehot.reshape(g, tg * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(g, tg, k, e)
    pos = jnp.sum(pos_in_expert * expert_onehot, axis=-1)           # (G,Tg,k)
    keep = pos < capacity
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity + 1,
                            dtype=x.dtype)[..., :capacity]          # (G,Tg,k,C)
    disp = jnp.einsum("gtke,gtkc->gtec", expert_onehot.astype(x.dtype), pos_oh)
    comb = jnp.einsum("gtk,gtke,gtkc->gtec",
                      gate_vals.astype(x.dtype),
                      expert_onehot.astype(x.dtype), pos_oh)

    bax = pspec.batch_axis(g)
    e_ax = pspec.model_axis(e)
    xin = jnp.einsum("gtec,gtd->egcd", disp, xt)                    # (E,G,C,d)
    # expert-sharded layout: the (data -> expert) reshard is MoE's all-to-all
    xin = pspec.constrain(xin, P(e_ax, bax, None, None))
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xin, params["w_gate"]))
        h = h * jnp.einsum("egcd,edf->egcf", xin, params["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("egcd,edf->egcf", xin, params["w_up"]))
    h = pspec.constrain(h, P(e_ax, bax, None, None))
    yout = jnp.einsum("egcf,efd->egcd", h, params["w_down"])        # (E,G,C,d)
    yout = pspec.constrain(yout, P(e_ax, bax, None, None))
    y = jnp.einsum("gtec,egcd->gtd", comb, yout).reshape(bsz, s, d)
    y = pspec.constrain(y, P(bax, None, None))
    return y, aux.astype(jnp.float32)
