"""Shared neural layers for the architecture substrate (pure JAX, pytree params).

All layers follow the convention:
  init_*(rng, cfg, ...) -> params dict
  apply signature (params, x, ...) -> y
Compute dtype follows x.dtype; norm statistics and softmax accumulate in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(rng, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# ------------------------------------------------------------------- norms
def init_norm(cfg: ModelConfig, d: int):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(params, x, eps: float = 1e-6):
    # statistics accumulate in f32 but the elementwise math stays in x.dtype:
    # a full astype(f32) of x makes XLA hoist the convert into the layer-scan
    # stash, doubling the remat memory (measured; EXPERIMENTS.md SS Perf)
    if "bias" in params:  # layernorm
        mu = jnp.mean(x, axis=-1, keepdims=True, dtype=jnp.float32)
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True,
                       dtype=jnp.float32) - jnp.square(mu)
        inv = jax.lax.rsqrt(var + eps)
        out = ((x - mu.astype(x.dtype)) * inv.astype(x.dtype)
               * params["scale"].astype(x.dtype)
               + params["bias"].astype(x.dtype))
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
        inv = jax.lax.rsqrt(ms + eps)
        out = x * inv.astype(x.dtype) * params["scale"].astype(x.dtype)
    return out


def rms_head_norm(x, scale, eps: float = 1e-6):
    """Per-head RMSNorm over head_dim (qwen3 qk-norm)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# ------------------------------------------------------------------- rotary
def rotary_freqs(cfg: ModelConfig, positions: jnp.ndarray) -> tuple:
    """positions: (..., S) int -> (sin, cos) of shape (..., S, hd/2), f32."""
    hd = cfg.hd
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    angles = positions[..., None].astype(jnp.float32) * inv
    return jnp.sin(angles), jnp.cos(angles)


def apply_rotary(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, hd); sin/cos: (..., S, hd/2) broadcast over heads.
    Rotation in x.dtype (sin/cos cast down) — see apply_norm's dtype note."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    s = sin[..., None, :].astype(x.dtype)
    c = cos[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ------------------------------------------------------------------- MLP
def init_mlp(rng, cfg: ModelConfig, d: int | None = None, ff: int | None = None):
    d = d or cfg.d_model
    ff = ff or cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(rng, 3)
    if cfg.act == "swiglu":
        return {"w_gate": dense_init(ks[0], d, ff, dt),
                "w_up": dense_init(ks[1], d, ff, dt),
                "w_down": dense_init(ks[2], ff, d, dt)}
    return {"w_up": dense_init(ks[0], d, ff, dt),
            "w_down": dense_init(ks[1], ff, d, dt)}


def apply_mlp(params, x, act: str = "swiglu"):
    from jax.sharding import PartitionSpec as P
    from repro.models import pspec
    if act == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    h = pspec.constrain(
        h, P(pspec.batch_axis(x.shape[0]), None, pspec.model_axis(h.shape[-1])))
    return h @ params["w_down"]


# ------------------------------------------------------------------- embed
def init_embedding(rng, cfg: ModelConfig):
    dt = dtype_of(cfg)
    k1, k2 = jax.random.split(rng)
    p = {"tok": (jax.random.normal(k1, (cfg.vocab, cfg.d_model), jnp.float32)
                 * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, cfg.d_model, cfg.vocab, dt,
                                  scale=cfg.d_model ** -0.5)
    return p


def embed_tokens(params, tokens):
    return jnp.take(params["tok"], tokens, axis=0)


def unembed(params, h):
    if "unembed" in params:
        return h @ params["unembed"]
    return h @ params["tok"].T
