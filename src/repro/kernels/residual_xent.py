"""Fused pseudo-residual kernel: r = onehot(y) - softmax(F), tiled over vocab.

This is GAL's protocol hot tensor at LM scale (DESIGN.md Sec. 5): the residual
Alice broadcasts is (tokens, vocab) with vocab up to 152k. A naive jnp
implementation materializes softmax(F) in HBM (a second vocab-sized tensor)
before subtracting; this kernel streams vocab tiles through VMEM twice:

  pass 1  row stats  — online (max, sumexp) accumulated across vocab tiles
  pass 2  residual   — emit onehot - exp(x - m)/l per tile

Tiles are (BT, BV) = (128, 512): MXU/VPU aligned (multiples of 128), VMEM
footprint ~BT*BV*4B = 256 KiB per ref. The vocab grid dimension is sequential
("arbitrary") so the stats carry is legal; the token dimension is parallel.

Callers: ``repro.kernels.ops.residual_xent`` (the jit'd entry the LM engine
uses) and — automatically — ``CrossEntropyLoss.residual`` for one-hot
targets at vocab >= ``repro.core.losses.XENT_KERNEL_MIN_CLASSES``, so any
GAL engine whose Alice loss is softmax cross entropy picks the kernel up
inside its scanned round step with no configuration.

TPU is the target; correctness is validated with interpret=True on CPU
against both the jnp reference and the generic autodiff ``Loss.residual``
oracle, including tied-max rows spanning tile seams and the -inf padded
vocab tail (``tests/test_kernels.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BT = 128   # token-block rows
BV = 512   # vocab-block cols
NEG_INF = -1e30


def _stats_kernel(x_ref, m_ref, l_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    x = x_ref[...].astype(jnp.float32)
    m_prev = m_ref[...]
    blk_max = jnp.max(x, axis=-1)
    m_new = jnp.maximum(m_prev, blk_max)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(
        jnp.exp(x - m_new[:, None]), axis=-1)
    m_ref[...] = m_new


def _resid_kernel(x_ref, lab_ref, m_ref, l_ref, out_ref):
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)
    sm = jnp.exp(x - m_ref[...][:, None]) / jnp.maximum(
        l_ref[...][:, None], 1e-30)
    cols = j * BV + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    onehot = (lab_ref[...][:, None] == cols).astype(jnp.float32)
    out_ref[...] = (onehot - sm).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "out_dtype"))
def residual_xent_kernel(logits: jnp.ndarray, labels: jnp.ndarray,
                         interpret: bool = True,
                         out_dtype=jnp.float32) -> jnp.ndarray:
    """logits: (T, V); labels: (T,) int32 -> residual (T, V) out_dtype.

    Pads T to BT and V to BV multiples (pad logits with -inf so softmax is
    unaffected; pad labels with -1 which never matches a column).
    """
    t, v = logits.shape
    tp = -(-t // BT) * BT
    vp = -(-v // BV) * BV
    x = jnp.pad(logits, ((0, tp - t), (0, vp - v)),
                constant_values=NEG_INF)
    lab = jnp.pad(labels.astype(jnp.int32), (0, tp - t), constant_values=-1)
    grid = (tp // BT, vp // BV)

    m, l = pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BT, BV), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((BT,), lambda i, j: (i,)),
                   pl.BlockSpec((BT,), lambda i, j: (i,))],
        out_shape=[jax.ShapeDtypeStruct((tp,), jnp.float32),
                   jax.ShapeDtypeStruct((tp,), jnp.float32)],
        interpret=interpret,
    )(x)

    out = pl.pallas_call(
        _resid_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BT, BV), lambda i, j: (i, j)),
            pl.BlockSpec((BT,), lambda i, j: (i,)),
            pl.BlockSpec((BT,), lambda i, j: (i,)),
            pl.BlockSpec((BT,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((BT, BV), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((tp, vp), out_dtype),
        interpret=interpret,
    )(x, lab, m, l)
    return out[:t, :v]
