"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def residual_xent_ref(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Pseudo-residual of cross entropy: r = onehot(labels) - softmax(logits).

    logits: (T, V) any float dtype; labels: (T,) int32. Returns f32 (T, V).
    This is the tensor Alice broadcasts each GAL round (paper Alg. 1 step 1)
    for an LM-scale overarching loss.
    """
    sm = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    return onehot - sm


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True,
                        window: Optional[int] = None) -> jnp.ndarray:
    """Reference GQA attention. q: (B, S, H, hd); k,v: (B, S, KV, hd).
    Returns (B, S, H, hd) in q.dtype. Softmax in f32."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    qpos = jnp.arange(s)
    mask = None
    if causal:
        mask = qpos[:, None] >= qpos[None, :]
    if window is not None:
        wmask = qpos[:, None] - qpos[None, :] < window
        mask = wmask if mask is None else (mask & wmask)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v)
    return out.reshape(b, s, h, hd)
