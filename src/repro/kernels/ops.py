"""Public jit'd entry points for the Pallas kernels.

On this CPU container kernels run with interpret=True (Python emulation of
the kernel body); on TPU set REPRO_PALLAS_INTERPRET=0 to lower for real.
"""
from __future__ import annotations

import os
from typing import Optional

import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.residual_xent import residual_xent_kernel
from repro.kernels import ref

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def residual_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                  use_kernel: bool = True) -> jnp.ndarray:
    """Pseudo-residual r = onehot(labels) - softmax(logits).

    logits: (..., V); labels: (...,) int32. Returns f32 residual.
    """
    lead = logits.shape[:-1]
    v = logits.shape[-1]
    flat = logits.reshape(-1, v)
    lab = labels.reshape(-1)
    if use_kernel:
        out = residual_xent_kernel(flat, lab, interpret=INTERPRET)
    else:
        out = ref.residual_xent_ref(flat, lab)
    return out.reshape(*lead, v)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: Optional[int] = None,
                    use_kernel: bool = True) -> jnp.ndarray:
    """GQA flash attention. q: (B,S,H,hd); k,v: (B,S,KV,hd) -> (B,S,H,hd)."""
    if use_kernel:
        return flash_attention_kernel(q, k, v, causal=causal, window=window,
                                      interpret=INTERPRET)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
