"""Flash attention (causal / sliding-window / full) with GQA head mapping.

Online-softmax streaming over K/V tiles with f32 accumulators in VMEM
scratch; q/k/v tiles are BlockSpec-mapped per (batch*head, q-block, k-block).
Block shapes (BQ, BK) = (128, 128) align the MXU; per-step VMEM working set is
q(BQ,hd) + k(BK,hd) + v(BK,hd) + acc(BQ,hd) + p(BQ,BK) ~= 0.4 MiB at hd=128.

TPU-adaptation note (DESIGN.md Sec. 5): out-of-window / future K blocks are
masked rather than skipped; on real TPU a grid-skip via scalar prefetch would
drop them — recorded as a perf-pass candidate, irrelevant for interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 128
BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  seq_len: int, n_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                   # (BQ, hd)
    k = k_ref[0].astype(jnp.float32)                   # (BK, hd)
    v = v_ref[0].astype(jnp.float32)
    s = (q @ k.T) * scale                              # (BQ, BK)

    qpos = iq * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
    kpos = ik * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
    mask = kpos < seq_len                              # K padding
    if causal:
        mask = mask & (qpos >= kpos)
    if window is not None:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "interpret"))
def flash_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           causal: bool = True,
                           window: Optional[int] = None,
                           interpret: bool = True) -> jnp.ndarray:
    """q: (B, S, H, hd); k,v: (B, S, KV, hd) -> (B, S, H, hd) in q.dtype."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    sp = -(-s // max(BQ, BK)) * max(BQ, BK)
    qp = jnp.pad(q, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    # (B*H, S, hd) query-major layout; kv index derived in the BlockSpec map
    qf = jnp.moveaxis(qp, 2, 1).reshape(b * h, sp, hd)
    kf = jnp.moveaxis(kp, 2, 1).reshape(b * kv, sp, hd)
    vf = jnp.moveaxis(vp, 2, 1).reshape(b * kv, sp, hd)
    n_q, n_k = sp // BQ, sp // BK

    def kv_map(bh, iq, ik):
        return (bh // h) * kv + (bh % h) // g, ik, 0

    kernel = functools.partial(
        _flash_kernel, scale=hd ** -0.5, causal=causal, window=window,
        seq_len=s, n_k=n_k)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, BQ, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, BK, hd), kv_map),
            pl.BlockSpec((1, BK, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, BQ, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BQ, hd), jnp.float32),   # acc
            pltpu.VMEM((BQ,), jnp.float32),      # running max
            pltpu.VMEM((BQ,), jnp.float32),      # running sumexp
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(b, h, sp, hd)[:, :, :s]
    return jnp.moveaxis(out, 1, 2)
