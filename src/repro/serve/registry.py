"""Multi-tenant artifact registry: many fitted collaborations, one server.

One ``gal-artifact/v1`` directory (or in-memory compiled ``GALResult``)
per **tenant** — one fitted collaboration per customer. Registration is
cheap (a manifest peek via ``repro.checkpoint.artifact_info``, no array
reads); the arrays load **lazily** on the tenant's first request, and a
bounded registry (``max_loaded=``) evicts the least-recently-used tenant
— dropping its arrays AND its jit cache — while keeping the registration,
so the next request transparently reloads. Each loaded tenant owns ONE
``BucketedPredict`` (``serve.batcher``): the per-tenant jit cache that
every request through the service reuses, bounded at one compilation per
bucket size.

The registry refuses results it cannot serve deterministically: python-
engine results (round params live in Organization objects, not the
artifact form) and plans with noisy groups (the prediction-stage noise is
drawn at the PADDED batch shape, so bucket padding would change the
draws — serve noisy ensembles unbatched).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence

from repro.serve.batcher import BucketedPredict

__all__ = ["ArtifactRegistry", "TenantEntry", "request_widths"]


def request_widths(result: Any) -> List[Optional[int]]:
    """Per-org request slice widths, in org order, recovered from the
    plan + per-group stacking geometry (the same recipe the serve CLI
    uses for ``--load``). Higher-rank slices (images etc.) have no single
    width and come back as None — batching still works (rows are rows),
    only the width validation is skipped."""
    if result.plan is None or result.group_dims is None:
        raise ValueError(
            "only compiled-engine results serve through the registry: this "
            f"result ran engine={result.engine!r} with no execution plan "
            "attached — refit with engine='auto' or load an artifact")
    widths: List[Optional[int]] = [None] * result.plan.n_orgs
    for gi, g in enumerate(result.plan.groups):
        if result.group_pads[gi] is None:
            continue                      # higher-rank geometry: no width
        for j, i in enumerate(g.indices):
            widths[i] = int(result.group_dims[gi][j])
    return widths


@dataclass
class TenantEntry:
    """One loaded tenant: the result, its request geometry, and its
    jitted bucket cache."""
    tenant: str
    result: Any
    widths: List[Optional[int]]
    predict: BucketedPredict
    loads: int = 1

    def validate_request(self, xs: Sequence[Any]) -> None:
        """Reject a malformed request BEFORE it reaches a batch (a wrong
        slice would otherwise fail inside someone else's launch)."""
        if len(xs) != len(self.widths):
            raise ValueError(
                f"tenant {self.tenant!r} serves {len(self.widths)} "
                f"organizations, request carries {len(xs)} slices")
        rows = {int(x.shape[0]) for x in xs}
        if len(rows) != 1:
            raise ValueError(
                f"request slices disagree on the row count: {sorted(rows)}")
        for m, (x, w) in enumerate(zip(xs, self.widths)):
            if w is not None and int(x.shape[-1]) != w:
                raise ValueError(
                    f"tenant {self.tenant!r} org {m} expects "
                    f"{w}-column slices, request has {int(x.shape[-1])}")


@dataclass
class ArtifactRegistry:
    """Tenant id -> fitted collaboration, with lazy load + LRU eviction.

    ``max_loaded=None`` keeps every tenant resident; a bound makes this a
    cache over the artifact directories. ``losses``/``models`` resolve
    custom (non-registry) identities exactly as ``load_artifact`` does.
    """
    max_loaded: Optional[int] = None
    max_batch: int = 64
    donate: Optional[bool] = None
    losses: Optional[Dict[str, Any]] = None
    models: Optional[Dict[str, Any]] = None
    _sources: Dict[str, Any] = field(default_factory=dict)
    _loaded: "OrderedDict[str, TenantEntry]" = field(
        default_factory=OrderedDict)
    _load_counts: Dict[str, int] = field(default_factory=dict)
    _lock: threading.RLock = field(default_factory=threading.RLock)
    loads: int = 0
    hits: int = 0
    evictions: int = 0

    def __post_init__(self):
        if self.max_loaded is not None and self.max_loaded < 1:
            raise ValueError(f"max_loaded must be >= 1 or None, got "
                             f"{self.max_loaded}")

    # -- registration -------------------------------------------------------

    def register(self, tenant: str, source: Any) -> None:
        """Attach a tenant to an artifact directory (validated by a
        manifest peek — no arrays read) or an in-memory compiled
        ``GALResult``. Re-registering replaces the source and evicts any
        loaded copy of the old one."""
        if isinstance(source, (str, Path)):
            from repro.checkpoint import artifact_info
            info = artifact_info(source)        # raises on a non-artifact
            if info["n_orgs"] < 1:
                raise ValueError(f"{source}: artifact fits no organizations")
            source = Path(source)
        else:
            self._check_servable(source)
        with self._lock:
            self._sources[tenant] = source
            self._loaded.pop(tenant, None)

    def _check_servable(self, result: Any) -> List[Optional[int]]:
        widths = request_widths(result)         # needs a plan
        if any(g.noise_sigma > 0.0 for g in result.plan.groups):
            raise ValueError(
                "cannot serve a noisy-org plan through the bucketed "
                "batcher: prediction-stage noise is drawn at the padded "
                "batch shape, so padding would change the draws — serve "
                "noisy ensembles through result.predict directly")
        return widths

    def tenants(self) -> List[str]:
        with self._lock:
            return sorted(self._sources)

    def __contains__(self, tenant: str) -> bool:
        with self._lock:
            return tenant in self._sources

    def info(self, tenant: str) -> Dict[str, Any]:
        """The tenant's manifest summary WITHOUT loading it (path-backed
        tenants) or a result summary (in-memory ones)."""
        with self._lock:
            src = self._require(tenant)
        if isinstance(src, Path):
            from repro.checkpoint import artifact_info
            return {"tenant": tenant, "loaded": self.is_loaded(tenant),
                    **artifact_info(src)}
        return {"tenant": tenant, "loaded": self.is_loaded(tenant),
                "engine": src.engine, "rounds": src.rounds,
                "n_orgs": src.plan.n_orgs, "schema": None}

    def _require(self, tenant: str) -> Any:
        if tenant not in self._sources:
            raise ValueError(
                f"unknown tenant {tenant!r}: registered tenants are "
                f"{sorted(self._sources)}")
        return self._sources[tenant]

    # -- the serving path ---------------------------------------------------

    def get(self, tenant: str) -> TenantEntry:
        """The tenant's loaded entry, loading lazily on first touch and
        refreshing its LRU position. Loading past ``max_loaded`` evicts
        the least-recently-used tenant (arrays + jit cache)."""
        with self._lock:
            entry = self._loaded.get(tenant)
            if entry is not None:
                self._loaded.move_to_end(tenant)
                self.hits += 1
                return entry
            src = self._require(tenant)
            if isinstance(src, Path):
                from repro.checkpoint import load_artifact
                result = load_artifact(src, losses=self.losses,
                                       models=self.models)
            else:
                result = src
            widths = self._check_servable(result)
            count = self._load_counts.get(tenant, 0) + 1
            self._load_counts[tenant] = count
            entry = TenantEntry(
                tenant=tenant, result=result, widths=widths,
                predict=BucketedPredict(
                    (lambda xq, _r=result: _r.predict(xq)),
                    max_batch=self.max_batch, donate=self.donate),
                loads=count)
            self._loaded[tenant] = entry
            self.loads += 1
            while (self.max_loaded is not None
                   and len(self._loaded) > self.max_loaded):
                evicted, _ = self._loaded.popitem(last=False)
                self.evictions += 1
            return entry

    def is_loaded(self, tenant: str) -> bool:
        with self._lock:
            return tenant in self._loaded

    def evict(self, tenant: str) -> bool:
        """Drop a tenant's loaded arrays + jit cache (the registration
        stays; the next request reloads). Returns whether it was loaded."""
        with self._lock:
            dropped = self._loaded.pop(tenant, None)
            if dropped is not None:
                self.evictions += 1
            return dropped is not None

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "tenants": len(self._sources),
                "loaded": len(self._loaded),
                "loads": self.loads, "hits": self.hits,
                "evictions": self.evictions,
                "launches": {t: e.predict.launches
                             for t, e in self._loaded.items()},
            }
