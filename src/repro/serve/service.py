"""The GAL inference service: registry + per-tenant batching + load driver.

``GALService`` is the composition the ROADMAP's millions-of-users story
asks for: an ``ArtifactRegistry`` of fitted collaborations (lazy load,
LRU eviction, per-tenant jit-cache reuse) with ONE ``MicroBatcher`` per
tenant packing concurrent predict calls into bucketed device launches.
Per-tenant batching is what keeps tenants **isolated**: a flush only ever
concatenates rows of a single collaboration, so no request can land in
another customer's launch (pinned in ``tests/test_serve_batching.py``).

``run_load`` / ``run_serial`` are the measurement half: a thread-pool of
concurrent clients driving the service (batched) vs the same requests
issued one-at-a-time against the same artifacts (the unbatched baseline),
reporting requests/sec and p50/p99 **blocked latency** — the time a
client waits for its completed answer, not the pipelined dispatch rate.
``benchmarks/load.py`` turns these numbers into the ``serve_throughput``
/ ``serve_p99`` rows of the BENCH artifact; ``launch/serve.py --service``
prints them interactively.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.serve.batcher import MicroBatcher
from repro.serve.registry import ArtifactRegistry

__all__ = ["GALService", "run_load", "run_serial"]


class GALService:
    """Concurrent multi-tenant Prediction Stage server.

    ``submit(tenant, xs)`` validates the request against the tenant's
    fitted geometry and enqueues it on that tenant's batcher (created
    lazily, flusher thread per tenant unless ``auto_flush=False``);
    ``predict`` is the blocking convenience. ``clock``/``auto_flush``
    exist so the flush policy is testable with a fake clock."""

    def __init__(self, registry: ArtifactRegistry,
                 deadline_s: float = 0.002, flush_rows: int = 1,
                 clock: Callable[[], float] = time.monotonic,
                 auto_flush: bool = True):
        self.registry = registry
        self.deadline_s = float(deadline_s)
        self.flush_rows = int(flush_rows)
        self.clock = clock
        self.auto_flush = auto_flush
        self._batchers: Dict[str, MicroBatcher] = {}
        self._lock = threading.Lock()
        self._closed = False

    def _batcher(self, tenant: str) -> MicroBatcher:
        with self._lock:
            if self._closed:
                raise RuntimeError("service is closed")
            b = self._batchers.get(tenant)
            if b is None:
                b = MicroBatcher(
                    # resolved at flush time so registry eviction/reload
                    # works transparently underneath a live batcher
                    (lambda _t=tenant: self.registry.get(_t).predict),
                    deadline_s=self.deadline_s,
                    flush_rows=self.flush_rows,
                    clock=self.clock, auto_flush=self.auto_flush)
                self._batchers[tenant] = b
            return b

    def submit(self, tenant: str, xs: Sequence[Any]) -> Future:
        entry = self.registry.get(tenant)       # lazy load on first touch
        entry.validate_request(xs)
        return self._batcher(tenant).submit(xs)

    def predict(self, tenant: str, xs: Sequence[Any],
                timeout: Optional[float] = None):
        return self.submit(tenant, xs).result(timeout)

    def warmup(self, tenant: str) -> int:
        """Compile the tenant's full bucket cache up front (one launch per
        bucket size) so no live request pays a compile. Returns the
        number of buckets compiled."""
        entry = self.registry.get(tenant)
        return entry.predict.compile_buckets(entry.widths)

    def poll(self) -> int:
        """Manual flush pump (``auto_flush=False`` / fake-clock runs):
        flush every tenant whose deadline policy says a flush is due."""
        with self._lock:
            batchers = list(self._batchers.values())
        return sum(b.poll() for b in batchers)

    def flush(self) -> int:
        with self._lock:
            batchers = list(self._batchers.values())
        return sum(b.flush() for b in batchers)

    def close(self) -> None:
        with self._lock:
            self._closed = True
            batchers = list(self._batchers.values())
        for b in batchers:
            b.close()

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            per_tenant = {t: b.stats() for t, b in self._batchers.items()}
        return {"registry": self.registry.stats(), "tenants": per_tenant}


# --------------------------------------------------------------------------
# the load harness: concurrent clients vs the one-at-a-time baseline
# --------------------------------------------------------------------------

def _latency_stats(latencies: Sequence[float], wall: float,
                   clients: int) -> Dict[str, Any]:
    lat_ms = np.asarray(sorted(latencies)) * 1e3
    return {
        "requests": len(latencies),
        "clients": clients,
        "seconds": float(wall),
        "requests_per_sec": len(latencies) / wall,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "mean_ms": float(lat_ms.mean()),
    }


def run_load(service: GALService,
             requests: Sequence[Tuple[str, Sequence[Any]]],
             clients: int = 8, depth: int = 1) -> Dict[str, Any]:
    """Fire ``requests`` (a list of ``(tenant, xs)``) at the service from
    ``clients`` concurrent threads (request i goes to client i % clients,
    each client sequential — a closed-loop load generator). ``depth`` is
    the per-client pipeline: each client keeps up to ``depth`` requests
    in flight before draining them in submission order (``depth=1`` is
    the strict request/response client; ``depth>1`` models an async
    client multiplexing a connection, and is what lets the batcher see
    more than ``clients`` rows at once). Latency is measured per
    request, submit to completed result. Returns throughput +
    percentile stats."""
    if clients < 1:
        raise ValueError(f"clients must be >= 1, got {clients}")
    if depth < 1:
        raise ValueError(f"depth must be >= 1, got {depth}")
    latencies: List[float] = []
    lock = threading.Lock()

    def client(ci: int) -> None:
        lats = []
        mine = range(ci, len(requests), clients)
        for s in range(0, len(mine), depth):
            window = mine[s:s + depth]
            futs = []
            for ri in window:
                tenant, xs = requests[ri]
                futs.append((service.submit(tenant, xs),
                             time.perf_counter()))
            for fut, t_sub in futs:
                fut.result()
                lats.append(time.perf_counter() - t_sub)
        with lock:
            latencies.extend(lats)

    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=clients) as ex:
        # list() re-raises the first client exception instead of hiding it
        list(ex.map(client, range(clients)))
    wall = time.perf_counter() - t0
    return {**_latency_stats(latencies, wall, clients), "depth": depth}


def run_serial(registry: ArtifactRegistry,
               requests: Sequence[Tuple[str, Sequence[Any]]]
               ) -> Dict[str, Any]:
    """The one-request-at-a-time baseline: the SAME artifacts and the same
    bucketed jit cache, but every request is its own blocked device
    launch — no packing, no concurrency. This is what the batched
    throughput is measured against."""
    latencies = []
    t0 = time.perf_counter()
    for tenant, xs in requests:
        entry = registry.get(tenant)
        t1 = time.perf_counter()
        jax.block_until_ready(entry.predict(xs))
        latencies.append(time.perf_counter() - t1)
    wall = time.perf_counter() - t0
    return _latency_stats(latencies, wall, clients=1)
