"""The GAL inference service (docs/serving.md): multi-tenant artifact
registry + bucketed request batching over the Prediction Stage."""
from repro.serve.batcher import (BucketedPredict, MicroBatcher, bucket_for,
                                 bucket_sizes, pad_rows)
from repro.serve.registry import ArtifactRegistry, TenantEntry, request_widths
from repro.serve.service import GALService, run_load, run_serial

__all__ = [
    "ArtifactRegistry", "BucketedPredict", "GALService", "MicroBatcher",
    "TenantEntry", "bucket_for", "bucket_sizes", "pad_rows",
    "request_widths", "run_load", "run_serial",
]
