"""Request batching for the GAL Prediction Stage serving path.

Two layers:

* ``BucketedPredict`` — wraps one tenant's ``GALResult.predict`` into a
  jitted callable with **pad-to-bucket** batch shapes: a request of ``n``
  rows is zero-padded up to the smallest bucket (powers of two up to
  ``max_batch``) before the device launch and sliced back after. The jit
  cache therefore holds AT MOST ``len(bucket_sizes(max_batch))``
  compilations per tenant, no matter what request sizes arrive — the
  property that keeps a long-lived multi-tenant server from compiling
  itself to death. Padding rows are zeros and the prediction stage is
  row-independent (per-row model applies contracted with per-round
  weights), so the un-padded rows are **bitwise identical** to an
  unbatched ``predict`` call (pinned in ``tests/test_serve_batching.py``).
  On backends with buffer donation (GPU/TPU) the padded request buffers
  are donated to the launch — they are always freshly allocated by the
  packer, so the hot path never copies them.

* ``MicroBatcher`` — packs CONCURRENT predict calls into one device
  launch. ``submit(xs)`` enqueues a request and returns a
  ``concurrent.futures.Future``; a flush concatenates every pending
  request's rows, chunks them to ``max_batch``, launches each chunk
  through the tenant's ``BucketedPredict``, and resolves each future with
  its own rows as a zero-copy numpy view of the synced batch output
  (results are device-complete before delivery, so a resolved future IS
  a finished request). The flush policy is
  deadline-based: a flush fires as soon as ``flush_rows`` rows are
  pending, or when the oldest pending request has waited ``deadline_s``
  — whichever comes first. With the default ``flush_rows=1`` the
  background flusher runs *continuous batching*: it launches whatever is
  pending the moment the previous launch returns, so under concurrent
  load each launch naturally carries every request that arrived during
  the previous one. The clock is injectable (``clock=``) and the
  background thread optional (``auto_flush=False`` + ``poll()``/
  ``flush()``), so the deadline logic is testable without sleeping.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, List, Optional, Sequence

import jax
import numpy as np

__all__ = ["bucket_sizes", "bucket_for", "pad_rows", "BucketedPredict",
           "MicroBatcher"]


def bucket_sizes(max_batch: int) -> tuple:
    """The served batch shapes: powers of two up to ``max_batch``, plus
    ``max_batch`` itself when it is not a power of two. Every request is
    padded up to the smallest bucket that holds it, so this tuple is the
    complete set of batch dimensions the jit cache will ever see."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    sizes: List[int] = []
    b = 1
    while b < max_batch:
        sizes.append(b)
        b *= 2
    sizes.append(max_batch)
    return tuple(sizes)


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket holding ``n`` rows."""
    if n < 1:
        raise ValueError(f"a request needs at least one row, got {n}")
    for b in buckets:
        if n <= b:
            return int(b)
    raise ValueError(f"{n} rows exceed the largest bucket ({buckets[-1]}); "
                     f"chunk the request (MicroBatcher does)")


def pad_rows(xs: Sequence[Any], n_to: int) -> List[np.ndarray]:
    """Zero-pad each per-org slice from its row count up to ``n_to`` rows
    (host-side: the padded buffers are freshly allocated, which is what
    makes them safely donatable to the launch)."""
    out = []
    for x in xs:
        arr = np.asarray(x)
        n = arr.shape[0]
        if n == n_to:
            out.append(arr)
            continue
        pad = np.zeros((n_to - n,) + arr.shape[1:], arr.dtype)
        out.append(np.concatenate([arr, pad], axis=0))
    return out


class BucketedPredict:
    """One tenant's jitted, bucket-padded prediction path.

    ``donate=None`` enables input-buffer donation only on backends that
    implement it (GPU/TPU); on CPU donation is a no-op that would warn on
    every compile, so it stays off there unless forced."""

    def __init__(self, predict_fn: Callable, max_batch: int = 64,
                 donate: Optional[bool] = None):
        self.max_batch = int(max_batch)
        self.buckets = bucket_sizes(self.max_batch)
        if donate is None:
            donate = jax.default_backend() in ("gpu", "tpu")
        self.donate = bool(donate)
        self._jit = jax.jit(lambda xq: predict_fn(xq),
                            donate_argnums=(0,) if self.donate else ())
        self.launches = 0
        self.rows_launched = 0
        self.rows_padded = 0

    def __call__(self, xs: Sequence[Any]):
        """Serve up to ``max_batch`` rows: pad to the bucket, one launch,
        slice the real rows back out."""
        n = int(np.asarray(xs[0]).shape[0])
        b = bucket_for(n, self.buckets)
        out = self._jit(pad_rows(xs, b))
        self.launches += 1
        self.rows_launched += n
        self.rows_padded += b - n
        return out[:n]

    def compile_buckets(self, widths: Sequence[Optional[int]],
                        dtype=np.float32) -> int:
        """Warm the whole jit cache up front: launch one zero request per
        bucket size. Returns the number of buckets compiled. Only
        possible for tabular (2-D) request geometry — ``widths`` is the
        per-org slice width list."""
        if any(w is None for w in widths):
            raise ValueError("compile_buckets needs per-org slice widths "
                             "(tabular requests); serve a real request to "
                             "warm higher-rank geometries")
        for b in self.buckets:
            zeros = [np.zeros((b, int(w)), dtype) for w in widths]
            jax.block_until_ready(self._jit(zeros))
        return len(self.buckets)


class _Pending:
    __slots__ = ("xs", "rows", "future", "t_submit")

    def __init__(self, xs, rows, future, t_submit):
        self.xs, self.rows = xs, rows
        self.future, self.t_submit = future, t_submit


class MicroBatcher:
    """Packs concurrent ``submit`` calls into bucketed device launches.

    ``predict_resolver`` is called at flush time and must return the
    tenant's live ``BucketedPredict`` — resolving late (rather than
    capturing the callable at construction) is what lets a registry evict
    and lazily reload the tenant underneath a long-lived batcher.
    """

    def __init__(self, predict_resolver: Callable[[], BucketedPredict],
                 deadline_s: float = 0.002, flush_rows: int = 1,
                 clock: Callable[[], float] = time.monotonic,
                 auto_flush: bool = True):
        if flush_rows < 1:
            raise ValueError(f"flush_rows must be >= 1, got {flush_rows}")
        self._resolve = predict_resolver
        self.deadline_s = float(deadline_s)
        self.flush_rows = int(flush_rows)
        self.clock = clock
        self._cond = threading.Condition()
        self._pending: List[_Pending] = []
        self._pending_rows = 0
        self._closed = False
        # stats
        self.requests = 0
        self.rows = 0
        self.batches = 0
        self.max_batch_rows = 0
        self._thread: Optional[threading.Thread] = None
        if auto_flush:
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name="gal-serve-flusher")
            self._thread.start()

    # -- submission ---------------------------------------------------------

    def submit(self, xs: Sequence[Any]) -> Future:
        """Enqueue one request (a per-org list of row slices); the returned
        future resolves to the ``(rows, K)`` prediction once its batch has
        been launched AND the result is device-complete."""
        rows = int(np.asarray(xs[0]).shape[0])
        if rows < 1:
            raise ValueError("a request needs at least one row")
        fut: Future = Future()
        with self._cond:
            if self._closed:
                raise RuntimeError("batcher is closed")
            self._pending.append(_Pending(list(xs), rows, fut, self.clock()))
            self._pending_rows += rows
            # only the flusher thread ever waits on _cond; waking exactly
            # one waiter avoids a thundering herd on single-core hosts
            self._cond.notify()
        return fut

    # -- flushing -----------------------------------------------------------

    def _due(self, now: float) -> bool:
        if not self._pending:
            return False
        return (self._pending_rows >= self.flush_rows
                or now - self._pending[0].t_submit >= self.deadline_s)

    def poll(self) -> int:
        """Flush IF the deadline policy says a flush is due (manual
        pumping — what the fake-clock tests and ``auto_flush=False``
        deployments call). Returns the number of requests flushed."""
        with self._cond:
            if not self._due(self.clock()):
                return 0
        return self.flush()

    def flush(self) -> int:
        """Launch everything pending (chunked to ``max_batch`` rows per
        launch) and resolve the futures. Returns requests flushed."""
        with self._cond:
            pending, self._pending = self._pending, []
            self._pending_rows = 0
        if not pending:
            return 0
        try:
            predict = self._resolve()
            xs_cat = [np.concatenate([np.asarray(p.xs[m]) for p in pending],
                                     axis=0)
                      for m in range(len(pending[0].xs))]
            total = sum(p.rows for p in pending)
            outs = []
            for start in range(0, total, predict.max_batch):
                chunk = [x[start:start + predict.max_batch] for x in xs_cat]
                outs.append(predict(chunk))
            # one device->host sync for the whole batch; per-request
            # results are then zero-copy numpy views (slicing the jax
            # array instead would dispatch one device op PER REQUEST)
            out = np.concatenate([np.asarray(o) for o in outs], axis=0)
            ofs = 0
            for p in pending:
                p.future.set_result(out[ofs:ofs + p.rows])
                ofs += p.rows
            self.batches += 1
            self.requests += len(pending)
            self.rows += total
            self.max_batch_rows = max(self.max_batch_rows, total)
        except Exception as e:                      # noqa: BLE001
            for p in pending:
                if not p.future.done():
                    p.future.set_exception(e)
        return len(pending)

    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._closed and not self._pending:
                    self._cond.wait(timeout=0.1)
                if self._closed:
                    return
                # accumulation window: wait (up to the oldest request's
                # deadline) for flush_rows rows before launching
                now = self.clock()
                while (not self._closed and self._pending
                       and not self._due(now)):
                    remain = self.deadline_s - (now - self._pending[0].t_submit)
                    self._cond.wait(timeout=max(remain, 1e-4))
                    now = self.clock()
                if self._closed:
                    return
            self.flush()

    def close(self) -> None:
        """Stop the flusher and drain anything still pending."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        self.flush()

    def stats(self) -> dict:
        return {
            "requests": self.requests, "rows": self.rows,
            "batches": self.batches,
            "max_batch_rows": self.max_batch_rows,
            "rows_per_batch": self.rows / max(self.batches, 1),
        }
