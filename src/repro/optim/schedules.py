"""Learning-rate schedules (callables step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay(lr: float, total_steps: int, final_frac: float = 0.1):
    def sched(step):
        t = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)

    return sched


def linear_warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_decay(lr, max(total_steps - warmup_steps, 1), final_frac)

    def sched(step):
        step_f = step.astype(jnp.float32)
        warm = lr * step_f / max(warmup_steps, 1)
        return jnp.where(step_f < warmup_steps, warm, cos(step - warmup_steps))

    return sched


def gal_theory_rate(t, a0: float = 1.0):
    """Paper Thm 1 rate family: a_t with sum a_t = inf, sum a_t^2 < inf.

    a_t = a0 / (t + 1) satisfies both; used in the convergence property tests.
    """
    return a0 / (jnp.asarray(t, jnp.float32) + 1.0)
