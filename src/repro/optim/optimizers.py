"""Functional optimizers (optax-style init/update pairs) in pure JAX.

Built in-repo because the container ships no optax; the framework needs SGD
(paper's local CNN fits), Adam (assistance-weight fits, Table 9) and AdamW
(LM-scale local fits).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Any

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]  # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def _as_schedule(lr) -> Callable[[jnp.ndarray], jnp.ndarray]:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def sgd(lr, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        mu = jax.tree_util.tree_map(jnp.zeros_like, params) if momentum else None
        return {"step": jnp.zeros((), jnp.int32), "mu": mu}

    def update(grads, state, params=None):
        del params
        step = state["step"]
        lr_t = sched(step)
        if momentum:
            mu = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g, state["mu"], grads
            )
            if nesterov:
                upd = jax.tree_util.tree_map(
                    lambda m, g: -(lr_t) * (momentum * m + g), mu, grads
                )
            else:
                upd = jax.tree_util.tree_map(lambda m: -(lr_t) * m, mu)
            return upd, {"step": step + 1, "mu": mu}
        upd = jax.tree_util.tree_map(lambda g: -(lr_t) * g, grads)
        return upd, {"step": step + 1, "mu": None}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    """Adam; weight_decay here is *coupled* L2 (as torch.optim.Adam, used by the
    paper's assistance-weight fit: lr 1e-1, wd 5e-4)."""
    sched = _as_schedule(lr)

    def init(params):
        z = jax.tree_util.tree_map(jnp.zeros_like, params)
        return {"step": jnp.zeros((), jnp.int32), "m": z,
                "v": jax.tree_util.tree_map(jnp.zeros_like, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step - 1)
        if weight_decay:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p, grads, params
            )
        m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g), state["v"], grads
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        upd = jax.tree_util.tree_map(
            lambda m_, v_: -lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps), m, v
        )
        return upd, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    """AdamW: decoupled weight decay (LM-scale local fits)."""
    sched = _as_schedule(lr)
    base = adam(lr, b1=b1, b2=b2, eps=eps, weight_decay=0.0)

    def update(grads, state, params):
        upd, state = base.update(grads, state, params)
        if weight_decay:
            lr_t = sched(state["step"] - 1)
            upd = jax.tree_util.tree_map(
                lambda u, p: u - lr_t * weight_decay * p, upd, params
            )
        return upd, state

    return Optimizer(base.init, update)
