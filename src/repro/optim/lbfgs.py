"""Scalar line-search optimizers for the gradient assisted learning rate.

The paper line-searches eta with L-BFGS (Table 9, Fig. 4b/e). In 1-D, L-BFGS
reduces exactly to the secant (memory-1 BFGS) iteration; we implement that with
Armijo safeguarding plus a golden-section fallback used when the secant model
is ill-conditioned. Everything is jit-compatible (lax loops only).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

_GOLD = 0.6180339887498949  # 1/phi


def golden_section(fn, lo: float, hi: float, iters: int = 40):
    """Minimize scalar fn over [lo, hi] by golden-section search.

    The surviving interior probe's value is carried through the loop, so
    each iteration costs ONE fn evaluation (plus two to seed the bracket)
    instead of two — each fn eval is a full (N, K) ensemble-loss pass in
    the GAL engines. The interval still shrinks by 1/phi per iteration:
    golden spacing makes the kept probe land exactly on one of the next
    interval's probe points (1/phi^2 == 1 - 1/phi)."""
    a = jnp.asarray(lo, jnp.float32)
    b = jnp.asarray(hi, jnp.float32)
    d = _GOLD * (b - a)
    x1, x2 = b - d, a + d                 # x1 < x2 interior probes
    f1, f2 = fn(x1), fn(x2)

    def body(_, state):
        a, b, x1, x2, f1, f2 = state
        left = f1 < f2                    # min in [a, x2] else [x1, b]
        a_n = jnp.where(left, a, x1)
        b_n = jnp.where(left, x2, b)
        d_n = _GOLD * (b_n - a_n)
        x_new = jnp.where(left, b_n - d_n, a_n + d_n)
        f_new = fn(x_new)                 # the ONE fresh eval
        x1_n = jnp.where(left, x_new, x2)
        f1_n = jnp.where(left, f_new, f2)
        x2_n = jnp.where(left, x1, x_new)
        f2_n = jnp.where(left, f1, f_new)
        return (a_n, b_n, x1_n, x2_n, f1_n, f2_n)

    a, b, *_ = jax.lax.fori_loop(0, iters, body, (a, b, x1, x2, f1, f2))
    return 0.5 * (a + b)


def _bracket(fn, x0: float = 1.0, grow: float = 2.0, iters: int = 12):
    """Expand [0, x0] until fn stops decreasing at the right edge."""
    x0 = jnp.asarray(x0, jnp.float32)

    def body(_, state):
        hi, f_hi = state
        nhi = hi * grow
        f_nhi = fn(nhi)
        take = f_nhi < f_hi
        return (jnp.where(take, nhi, hi), jnp.where(take, f_nhi, f_hi))

    hi, _ = jax.lax.fori_loop(0, iters, body, (x0, fn(x0)))
    return hi


def scalar_lbfgs(fn, x0: float = 1.0, iters: int = 25, max_range: float = 64.0):
    """1-D L-BFGS (secant) minimization of fn, Armijo-safeguarded.

    Returns the minimizing scalar. fn must be differentiable (jax.grad-able).
    """
    g = jax.grad(fn)
    x0 = jnp.asarray(x0, jnp.float32)

    def body(_, state):
        x_prev, g_prev, x, gx = state
        denom = gx - g_prev
        # secant Hessian estimate; fall back to unit step when degenerate
        h = jnp.where(jnp.abs(denom) > 1e-12, (x - x_prev) / denom, 1.0)
        h = jnp.clip(h, 1e-4, max_range)
        step = -h * gx
        x_new = jnp.clip(x + step, -max_range, max_range)
        # Armijo halving (fixed 6 trials, branchless); fn(x) is hoisted —
        # each fn eval is a full ensemble-loss pass in the GAL engine
        f_x = fn(x)

        def armijo(_, xs):
            x_try, = xs
            worse = fn(x_try) > f_x + 1e-4 * gx * (x_try - x)
            return (jnp.where(worse, 0.5 * (x_try + x), x_try),)

        (x_new,) = jax.lax.fori_loop(0, 6, armijo, (x_new,))
        return (x, gx, x_new, g(x_new))

    x_prev = x0 - 0.5
    state = (x_prev, g(x_prev), x0, g(x0))
    state = jax.lax.fori_loop(0, iters, body, state)
    return state[2]


def line_search(fn, method: str = "lbfgs", x0: float = 1.0, iters: int = 25):
    """Unified entry used by the GAL engines. method in {lbfgs, golden,
    constant}. Built from lax loops only, so it traces cleanly inside the
    fused engine's jitted round step (no retracing per round)."""
    if method == "constant":
        return jnp.asarray(x0, jnp.float32)
    if method == "golden":
        hi = _bracket(fn, x0=jnp.maximum(x0, 1e-3))
        return golden_section(fn, 0.0, hi, iters=max(iters, 40))
    if method == "lbfgs":
        return scalar_lbfgs(fn, x0=x0, iters=iters)
    raise ValueError(f"unknown line-search method {method!r}")
