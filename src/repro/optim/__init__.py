from repro.optim.optimizers import sgd, adam, adamw, apply_updates, Optimizer
from repro.optim.schedules import constant, cosine_decay, linear_warmup_cosine
from repro.optim.lbfgs import scalar_lbfgs, golden_section
