"""Org execution planner: who can share a compiled group, and why not.

The fused GAL engines (``repro.core.engine``) trace ONE round step and scan
it; until this module existed that was only possible when every organization
shared a single model config — the paper's heterogeneous scenarios (model
autonomy, per-org local losses, noisy orgs, Table 5/6) all fell back to the
Python reference loop. The planner dissolves that wall: it partitions the
organizations into *homogeneous groups* keyed by

    (model signature, local-loss exponent q, noise sigma, slice rank
     [, slice width when the model's random init is width-dependent,
      trailing shape for higher-rank inputs])

so that each group can be ``jax.vmap``-ed over one stacked input block, and
ALL groups run inside the *same* traced round step — their fitted values
concatenated along the org axis (in original org order) before the step-4
weight fit. A plan either *compiles* (``plan.compiled``) or carries a
human-readable ``reason`` naming the first organization that forces the
Python fallback (Deep Model Sharing, a non-scan-safe model, a local loss
with no ell_q exponent, inputs that do not share a sample axis). Width- or
shape-driven splits never block compilation — they just produce more groups,
recorded in ``plan.notes``.

``repro.core.gal.fit`` dispatches purely on the plan; ``plan_lm_orgs``
applies the same grouping to the LM-scale path (``repro.core.gal_lm``),
whose fused engine additionally requires a single group.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class OrgGroup:
    """One homogeneous slice of the org list: same model config, same local
    ell_q, same noise sigma, stackable inputs. ``indices`` are positions in
    the fitted org list (the engine's concat/permutation coordinates);
    ``org_ids`` are the ``Organization.index`` values (the RNG identity each
    engine folds into the round key)."""
    indices: Tuple[int, ...]
    org_ids: Tuple[int, ...]
    model: Any
    local_loss: Any
    noise_sigma: float = 0.0

    @property
    def size(self) -> int:
        return len(self.indices)

    def describe(self) -> str:
        q = getattr(self.local_loss, "q", None)
        bits = [f"{type(self.model).__name__} x{self.size}"]
        if q is not None:
            bits.append(f"q={float(q):g}")
        if self.noise_sigma:
            bits.append(f"sigma={float(self.noise_sigma):g}")
        return " ".join(bits)


@dataclass(frozen=True)
class ExecutionPlan:
    """The planner's verdict: the group partition plus, when the compiled
    engines cannot run it, the human-readable reason why."""
    groups: Tuple[OrgGroup, ...]
    reason: Optional[str] = None
    notes: Tuple[str, ...] = ()

    @property
    def compiled(self) -> bool:
        return self.reason is None

    @property
    def n_groups(self) -> int:
        return len(self.groups)

    @property
    def n_orgs(self) -> int:
        return sum(g.size for g in self.groups)

    @property
    def noisy(self) -> bool:
        return any(g.noise_sigma > 0.0 for g in self.groups)

    @property
    def homogeneous(self) -> bool:
        """One noiseless group — the legacy scan/shard engines' contract."""
        return self.n_groups == 1 and not self.noisy

    @property
    def permutation(self) -> Tuple[int, ...]:
        """Org positions in group-concatenation order."""
        return tuple(i for g in self.groups for i in g.indices)

    @property
    def inverse_permutation(self) -> Tuple[int, ...]:
        """Maps group-concatenated rows back to original org order."""
        perm = self.permutation
        inv = [0] * len(perm)
        for pos, i in enumerate(perm):
            inv[i] = pos
        return tuple(inv)

    def fallback(self, reason: str) -> "ExecutionPlan":
        """Degrade to the Python path for an engine-level reason (e.g. a
        host-side metric_fn); the first reason recorded wins."""
        if self.reason is not None:
            return self
        return replace(self, reason=reason)

    def describe(self) -> str:
        head = f"{self.n_groups} group{'s' if self.n_groups != 1 else ''}: "
        body = " | ".join(g.describe() for g in self.groups)
        tail = f"  [fallback: {self.reason}]" if self.reason else ""
        return head + "[" + body + "]" + tail


def _pad_invariant(model: Any, q) -> bool:
    inv = getattr(model, "pad_invariant", False)
    if callable(inv):
        inv = inv(q)
    return bool(inv)


def _group_key(org: Any) -> tuple:
    """Grouping key; orgs with equal keys share one vmapped stack."""
    x = org.x_train
    q = getattr(org.local_loss, "q", None)
    extra: tuple
    if x.ndim != 2:
        # higher-rank inputs stack unpadded: the full trailing shape must
        # match within a group
        extra = ("shape", tuple(int(s) for s in x.shape[1:]))
    elif _pad_invariant(org.model, q):
        # zero-pad columns are inert for this fit: widths may mix freely
        extra = ("padded",)
    else:
        # width-dependent random init (MLP, Linear q!=2, ...): padding would
        # silently change the draws, so each width gets its own group
        extra = ("width", int(x.shape[-1]))
    return (type(org.model), org.model, q,
            float(getattr(org, "noise_sigma", 0.0)), extra)


def plan_orgs(orgs: Sequence[Any],
              eval_sets: Optional[Dict[str, tuple]] = None) -> ExecutionPlan:
    """Partition ``orgs`` into compiled-engine groups, or say why not.

    The returned plan always carries the group partition (useful for
    diagnostics even when ineligible); ``plan.compiled`` is the single
    eligibility verdict the engine dispatch consumes.
    """
    if not orgs:
        return ExecutionPlan((), reason="no organizations to plan")

    reason = None
    notes: List[str] = []
    for i, org in enumerate(orgs):
        if getattr(org, "dms", False):
            reason = (f"organization {org.index} uses Deep Model Sharing "
                      f"(its per-round extractor/head state cannot be "
                      f"stacked into a scanned round step)")
            break
        if not getattr(org.model, "scan_safe", False):
            reason = (f"organization {org.index}'s model "
                      f"{type(org.model).__name__} is not scan-safe "
                      f"(fit/apply not declared pure-jnp)")
            break
        if getattr(org.local_loss, "q", None) is None:
            reason = (f"organization {org.index}'s local_loss "
                      f"{getattr(org.local_loss, '__name__', org.local_loss)}"
                      f" has no exponent q (not an ell_q loss)")
            break
        x = org.x_train
        if not (hasattr(x, "ndim") and hasattr(x, "shape")):
            reason = f"organization {org.index}'s input is not an array"
            break
        if x.shape[0] != orgs[0].x_train.shape[0]:
            reason = (f"org inputs do not share a sample axis: organization "
                      f"{org.index} has {x.shape[0]} rows, organization "
                      f"{orgs[0].index} has {orgs[0].x_train.shape[0]}")
            break

    if reason is None and eval_sets:
        reason = _check_eval_sets(orgs, eval_sets)

    # group by key, preserving first-occurrence order (key equality is
    # checked by value — frozen-dataclass models compare by config)
    keys: List[tuple] = []
    members: List[List[int]] = []
    for i, org in enumerate(orgs):
        try:
            k = _group_key(org)
        except Exception:
            k = ("unkeyed", i)
        for gi, existing in enumerate(keys):
            if existing == k:
                members[gi].append(i)
                break
        else:
            keys.append(k)
            members.append([i])

    groups = tuple(
        OrgGroup(
            indices=tuple(idx),
            org_ids=tuple(int(orgs[i].index) for i in idx),
            model=orgs[idx[0]].model,
            local_loss=orgs[idx[0]].local_loss,
            noise_sigma=float(getattr(orgs[idx[0]], "noise_sigma", 0.0)),
        )
        for idx in members
    )
    width_split = [k for k in keys if k[-1] and k[-1][0] == "width"]
    if len(width_split) > 1 and reason is None:
        notes.append("width-dependent model init: groups split per slice "
                     "width instead of zero-padding")
    return ExecutionPlan(groups=groups, reason=reason, notes=tuple(notes))


def _check_eval_sets(orgs: Sequence[Any],
                     eval_sets: Dict[str, tuple]) -> Optional[str]:
    for name, (xs_e, _) in eval_sets.items():
        if len(xs_e) != len(orgs):
            return (f"eval set {name!r} has {len(xs_e)} slices for "
                    f"{len(orgs)} organizations")
        for i, (org, x_e) in enumerate(zip(orgs, xs_e)):
            x = org.x_train
            if not (hasattr(x_e, "ndim") and hasattr(x_e, "shape")):
                return f"eval set {name!r} slice {i} is not an array"
            if x_e.ndim != x.ndim:
                return (f"eval set {name!r} slice {i} has rank {x_e.ndim}, "
                        f"train slice has rank {x.ndim}")
            if x_e.shape[0] != xs_e[0].shape[0]:
                return (f"eval set {name!r} slices do not share a sample "
                        f"axis")
            if x.ndim == 2:
                if int(x_e.shape[-1]) != int(x.shape[-1]):
                    return (f"eval set {name!r} slice {i} has width "
                            f"{int(x_e.shape[-1])}, organization "
                            f"{org.index} was fit on width "
                            f"{int(x.shape[-1])}")
            elif x_e.shape[1:] != x.shape[1:]:
                return (f"eval set {name!r} slice {i} shape "
                        f"{tuple(x_e.shape[1:])} != train shape "
                        f"{tuple(x.shape[1:])}")
    return None


def plan_lm_orgs(orgs: Sequence[Any]) -> ExecutionPlan:
    """The same grouping for LM-scale organizations (``core.gal_lm``):
    groups keyed by (architecture config, local lr). The fused LM path
    additionally requires a single group — ``fit_lm`` raises with
    ``plan.describe()`` otherwise."""
    if not orgs:
        return ExecutionPlan((), reason="no organizations to plan")
    reason = None
    for org in orgs:
        if org.params is None or org._train_step is None:
            reason = (f"LM organization {org.index} is not initialized "
                      f"(call .init(rng) first)")
            break
    keys: List[tuple] = []
    members: List[List[int]] = []
    for i, org in enumerate(orgs):
        k = (org.cfg, org.lr)
        for gi, existing in enumerate(keys):
            if existing == k:
                members[gi].append(i)
                break
        else:
            keys.append(k)
            members.append([i])
    groups = tuple(
        OrgGroup(indices=tuple(idx),
                 org_ids=tuple(int(orgs[i].index) for i in idx),
                 model=orgs[idx[0]].cfg, local_loss=None)
        for idx in members
    )
    return ExecutionPlan(groups=groups, reason=reason)
