"""Org execution planner: who can share a compiled group, and why not.

The fused GAL engines (``repro.core.engine``) trace ONE round step and scan
it; until this module existed that was only possible when every organization
shared a single model config — the paper's heterogeneous scenarios (model
autonomy, per-org local losses, noisy orgs, Table 5/6) all fell back to the
Python reference loop. The planner dissolves that wall: it partitions the
organizations into *homogeneous groups* keyed by

    (model signature, Deep-Model-Sharing flag, local loss [the ell_q
     exponent, or the loss callable itself for custom traceable losses],
     noise sigma, slice rank
     [, slice width when the model's random init is width-dependent,
      trailing shape for higher-rank inputs])

so that each group can be ``jax.vmap``-ed over one stacked input block, and
ALL groups run inside the *same* traced round step — their fitted values
concatenated along the org axis (in original org order) before the step-4
weight fit. Deep Model Sharing (paper Sec. 4.2/5) compiles too: a DMS
group is keyed by its extractor signature (the model config) and its fit
is traced with the shared extractor in the scan carry and the per-round
heads accumulated on a stacked ``(T, ...)`` axis — see
``repro.core.engine``. Custom local losses compile whenever they are
jax-traceable (probed with ``jax.eval_shape``); ell_q losses keep their
exponent as the group key, other losses key by callable identity.

A plan either *compiles* (``plan.compiled``) or carries a human-readable
``reason`` naming the first organization that forces the Python fallback —
after this planner generation the true fallbacks are genuinely non-array
inputs, models not declared ``scan_safe`` (or DMS models without the
extractor/head interface), and local losses that fail to trace. Width- or
shape-driven splits never block compilation — they just produce more groups,
recorded in ``plan.notes``.

``repro.core.gal.fit`` dispatches purely on the plan; ``plan_lm_orgs``
applies the same grouping to the LM-scale path (``repro.core.gal_lm``),
whose fused engine additionally requires a single group.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class OrgGroup:
    """One homogeneous slice of the org list: same model config, same local
    loss, same noise sigma, same DMS flag, stackable inputs. ``indices``
    are positions in the fitted org list (the engine's concat/permutation
    coordinates); ``org_ids`` are the ``Organization.index`` values (the
    RNG identity each engine folds into the round key)."""
    indices: Tuple[int, ...]
    org_ids: Tuple[int, ...]
    model: Any
    local_loss: Any
    noise_sigma: float = 0.0
    dms: bool = False

    @property
    def size(self) -> int:
        return len(self.indices)

    def describe(self) -> str:
        q = getattr(self.local_loss, "q", None)
        bits = [f"{type(self.model).__name__} x{self.size}"]
        if self.dms:
            bits.append("DMS")
        if q is not None:
            bits.append(f"q={float(q):g}")
        elif self.local_loss is not None:
            bits.append(
                f"loss={getattr(self.local_loss, '__name__', 'custom')}")
        if self.noise_sigma:
            bits.append(f"sigma={float(self.noise_sigma):g}")
        return " ".join(bits)


@dataclass(frozen=True)
class ExecutionPlan:
    """The planner's verdict: the group partition plus, when the compiled
    engines cannot run it, the human-readable reason why."""
    groups: Tuple[OrgGroup, ...]
    reason: Optional[str] = None
    notes: Tuple[str, ...] = ()

    @property
    def compiled(self) -> bool:
        return self.reason is None

    @property
    def n_groups(self) -> int:
        return len(self.groups)

    @property
    def n_orgs(self) -> int:
        return sum(g.size for g in self.groups)

    @property
    def noisy(self) -> bool:
        return any(g.noise_sigma > 0.0 for g in self.groups)

    @property
    def has_dms(self) -> bool:
        """True when any group runs Deep Model Sharing (a stateful carry in
        the scanned round step — grouped-engine territory)."""
        return any(g.dms for g in self.groups)

    @property
    def homogeneous(self) -> bool:
        """One noiseless fresh-fit group — the legacy scan/shard engines'
        contract. DMS plans are never homogeneous: their extractor/head
        carry belongs to the grouped engine."""
        return self.n_groups == 1 and not self.noisy and not self.has_dms

    @property
    def permutation(self) -> Tuple[int, ...]:
        """Org positions in group-concatenation order."""
        return tuple(i for g in self.groups for i in g.indices)

    @property
    def inverse_permutation(self) -> Tuple[int, ...]:
        """Maps group-concatenated rows back to original org order."""
        perm = self.permutation
        inv = [0] * len(perm)
        for pos, i in enumerate(perm):
            inv[i] = pos
        return tuple(inv)

    def describe(self) -> str:
        head = f"{self.n_groups} group{'s' if self.n_groups != 1 else ''}: "
        body = " | ".join(g.describe() for g in self.groups)
        tail = f"  [fallback: {self.reason}]" if self.reason else ""
        return head + "[" + body + "]" + tail


def _pad_invariant(model: Any, q) -> bool:
    inv = getattr(model, "pad_invariant", False)
    if callable(inv):
        inv = inv(q)
    return bool(inv)


# the duck-typed surface a model must expose for the traced Deep Model
# Sharing fit (shared extractor in the scan carry, stacked per-round heads)
DMS_INTERFACE = ("init", "features", "init_head", "apply_head")


def dms_traceable(model: Any) -> bool:
    """True when ``model`` can join a compiled DMS group: pure-jnp
    (``scan_safe``) AND exposes the shared-extractor interface."""
    return (getattr(model, "scan_safe", False)
            and all(hasattr(model, a) for a in DMS_INTERFACE))


def dms_interface_reason(org: Any) -> Optional[str]:
    """The human-readable reason when a DMS org's model lacks the
    extractor/head surface, or None when it is complete. The ONE source of
    this diagnostic: the planner uses it for the compiled-engine verdict
    and ``gal.fit`` re-raises it for the python path, which needs the same
    four methods."""
    missing = [a for a in DMS_INTERFACE if not hasattr(org.model, a)]
    if not missing:
        return None
    return (f"organization {org.index} uses Deep Model Sharing but its "
            f"model {type(org.model).__name__} lacks the "
            f"shared-extractor interface ({'/'.join(missing)})")


def loss_traceable(local_loss: Any, probe_shape: Optional[tuple] = None
                   ) -> bool:
    """True when a custom (non-ell_q) local loss traces to a scalar under
    ``jax.eval_shape`` — the compiled engines differentiate it inside the
    scanned round step, so host-side callbacks cannot compile.
    ``probe_shape`` is the real residual shape (N, K) when the caller
    knows it (``gal.fit`` passes y's shape), so shape-dependent losses —
    e.g. per-class weights broadcasting against K — are probed against
    the shapes they will actually see; the (2, 1) fallback only covers
    planning without a target."""
    import jax
    import jax.numpy as jnp
    try:
        spec = jax.ShapeDtypeStruct(tuple(probe_shape or (2, 1)),
                                    jnp.float32)
        out = jax.eval_shape(local_loss, spec, spec)
        return getattr(out, "shape", None) == ()
    except Exception:
        return False


def _group_key(org: Any) -> tuple:
    """Grouping key; orgs with equal keys share one vmapped stack."""
    x = org.x_train
    q = getattr(org.local_loss, "q", None)
    # ell_q losses group by exponent value; custom traceable losses by the
    # loss callable itself (identity — two orgs share a group only when
    # they share the object)
    loss_key = q if q is not None else org.local_loss
    dms = bool(getattr(org, "dms", False))
    extra: tuple
    if x.ndim != 2:
        # higher-rank inputs stack unpadded: the full trailing shape must
        # match within a group
        extra = ("shape", tuple(int(s) for s in x.shape[1:]))
    elif not dms and _pad_invariant(org.model, q):
        # zero-pad columns are inert for this fit: widths may mix freely
        extra = ("padded",)
    else:
        # width-dependent random init (MLP, Linear q!=2, any DMS extractor
        # init, ...): padding would silently change the draws, so each
        # width gets its own group
        extra = ("width", int(x.shape[-1]))
    return (type(org.model), org.model, loss_key, dms,
            float(getattr(org, "noise_sigma", 0.0)), extra)


def plan_orgs(orgs: Sequence[Any],
              eval_sets: Optional[Dict[str, tuple]] = None,
              probe_shape: Optional[tuple] = None) -> ExecutionPlan:
    """Partition ``orgs`` into compiled-engine groups, or say why not.

    The returned plan always carries the group partition (useful for
    diagnostics even when ineligible); ``plan.compiled`` is the single
    eligibility verdict the engine dispatch consumes. ``probe_shape`` is
    the residual shape (N, K) custom losses will be traced at, when known.
    """
    if not orgs:
        return ExecutionPlan((), reason="no organizations to plan")

    reason = None
    notes: List[str] = []
    for i, org in enumerate(orgs):
        if not getattr(org.model, "scan_safe", False):
            reason = (f"organization {org.index}'s model "
                      f"{type(org.model).__name__} is not scan-safe "
                      f"(fit/apply not declared pure-jnp)")
            break
        if getattr(org, "dms", False) and not dms_traceable(org.model):
            reason = (dms_interface_reason(org)
                      or (f"organization {org.index} uses Deep Model "
                          f"Sharing but its model "
                          f"{type(org.model).__name__} is not scan-safe"))
            break
        if (getattr(org.local_loss, "q", None) is None
                and not loss_traceable(org.local_loss, probe_shape)):
            reason = (f"organization {org.index}'s local_loss "
                      f"{getattr(org.local_loss, '__name__', org.local_loss)}"
                      f" is not jax-traceable to a scalar (the compiled "
                      f"engines differentiate it inside the scanned round "
                      f"step)")
            break
        x = org.x_train
        if not (hasattr(x, "ndim") and hasattr(x, "shape")):
            reason = f"organization {org.index}'s input is not an array"
            break
        if x.shape[0] != orgs[0].x_train.shape[0]:
            reason = (f"org inputs do not share a sample axis: organization "
                      f"{org.index} has {x.shape[0]} rows, organization "
                      f"{orgs[0].index} has {orgs[0].x_train.shape[0]}")
            break

    if reason is None and eval_sets:
        reason = _check_eval_sets(orgs, eval_sets)

    # group by key, preserving first-occurrence order (key equality is
    # checked by value — frozen-dataclass models compare by config)
    keys: List[tuple] = []
    members: List[List[int]] = []
    for i, org in enumerate(orgs):
        try:
            k = _group_key(org)
        except Exception:
            k = ("unkeyed", i)
        for gi, existing in enumerate(keys):
            if existing == k:
                members[gi].append(i)
                break
        else:
            keys.append(k)
            members.append([i])

    groups = tuple(
        OrgGroup(
            indices=tuple(idx),
            org_ids=tuple(int(orgs[i].index) for i in idx),
            model=orgs[idx[0]].model,
            local_loss=orgs[idx[0]].local_loss,
            noise_sigma=float(getattr(orgs[idx[0]], "noise_sigma", 0.0)),
            dms=bool(getattr(orgs[idx[0]], "dms", False)),
        )
        for idx in members
    )
    width_split = [k for k in keys if k[-1] and k[-1][0] == "width"]
    if len(width_split) > 1 and reason is None:
        notes.append("width-dependent model init: groups split per slice "
                     "width instead of zero-padding")
    return ExecutionPlan(groups=groups, reason=reason, notes=tuple(notes))


def _check_eval_sets(orgs: Sequence[Any],
                     eval_sets: Dict[str, tuple]) -> Optional[str]:
    for name, (xs_e, _) in eval_sets.items():
        if len(xs_e) != len(orgs):
            return (f"eval set {name!r} has {len(xs_e)} slices for "
                    f"{len(orgs)} organizations")
        for i, (org, x_e) in enumerate(zip(orgs, xs_e)):
            x = org.x_train
            if not (hasattr(x_e, "ndim") and hasattr(x_e, "shape")):
                return f"eval set {name!r} slice {i} is not an array"
            if x_e.ndim != x.ndim:
                return (f"eval set {name!r} slice {i} has rank {x_e.ndim}, "
                        f"train slice has rank {x.ndim}")
            if x_e.shape[0] != xs_e[0].shape[0]:
                return (f"eval set {name!r} slices do not share a sample "
                        f"axis")
            if x.ndim == 2:
                if int(x_e.shape[-1]) != int(x.shape[-1]):
                    return (f"eval set {name!r} slice {i} has width "
                            f"{int(x_e.shape[-1])}, organization "
                            f"{org.index} was fit on width "
                            f"{int(x.shape[-1])}")
            elif x_e.shape[1:] != x.shape[1:]:
                return (f"eval set {name!r} slice {i} shape "
                        f"{tuple(x_e.shape[1:])} != train shape "
                        f"{tuple(x.shape[1:])}")
    return None


_GROUP_MANIFEST_FIELDS = ("indices", "org_ids", "model", "local_loss",
                          "noise_sigma", "dms")


def plan_to_manifest(plan: ExecutionPlan, model_spec, loss_spec) -> Dict:
    """Serialize a plan's group partition for the artifact manifest.

    The codecs are injected (``repro.checkpoint.checkpoint.model_spec`` /
    ``loss_spec``) so the planner stays free of any persistence-layer
    imports. The manifest carries everything ``plan_from_manifest`` needs
    to rebuild a prediction-capable plan, and everything ``plan_mismatch``
    needs to verify a resume-time org set against the fitted one."""
    return {
        "groups": [
            {"indices": list(g.indices), "org_ids": list(g.org_ids),
             "model": model_spec(g.model),
             "local_loss": loss_spec(g.local_loss),
             "noise_sigma": float(g.noise_sigma), "dms": bool(g.dms)}
            for g in plan.groups
        ],
        "notes": list(plan.notes),
    }


def plan_from_manifest(manifest: Dict, model_from_spec,
                       loss_from_spec) -> ExecutionPlan:
    """Inverse of ``plan_to_manifest``: rebuild a compiled ExecutionPlan
    (no fallback reason — only compiled plans are ever saved) with models
    and losses re-resolved through the injected codecs."""
    groups = tuple(
        OrgGroup(
            indices=tuple(int(i) for i in gm["indices"]),
            org_ids=tuple(int(i) for i in gm["org_ids"]),
            model=model_from_spec(gm["model"]),
            local_loss=loss_from_spec(gm["local_loss"]),
            noise_sigma=float(gm["noise_sigma"]),
            dms=bool(gm["dms"]),
        )
        for gm in manifest["groups"]
    )
    return ExecutionPlan(groups=groups,
                         notes=tuple(manifest.get("notes", ())))


def plan_mismatch(plan: ExecutionPlan, manifest: Dict, model_spec,
                  loss_spec) -> Optional[str]:
    """Compare a freshly planned org set against an artifact's plan
    manifest; None when they match group for group, else a human-readable
    reason naming the first divergence. This is the resume-time compat
    gate: the restored round-scan carry is only meaningful when the new
    orgs plan into the *identical* partition (same group order, same
    member indices/ids, same model configs, same loss identities, same
    noise sigmas, same DMS flags)."""
    mine = plan_to_manifest(plan, model_spec, loss_spec)["groups"]
    theirs = manifest["groups"]
    if len(mine) != len(theirs):
        return (f"artifact plan has {len(theirs)} group(s), the supplied "
                f"organizations plan into {len(mine)}")
    for gi, (a, b) in enumerate(zip(mine, theirs)):
        for field_ in _GROUP_MANIFEST_FIELDS:
            if a[field_] != b[field_]:
                return (f"group {gi} {field_} mismatch: artifact has "
                        f"{b[field_]!r}, the supplied organizations have "
                        f"{a[field_]!r}")
    return None


def plan_growth_mismatch(plan: ExecutionPlan, manifest: Dict, model_spec,
                         loss_spec) -> Optional[str]:
    """The mid-fit-join relaxation of ``plan_mismatch``: None when the new
    org set is a *compatible growth* of the artifact's — every fitted org
    keeps its position, id, model, loss, noise and DMS flag, and the extra
    orgs only ever APPEND (new members at the tail of an existing fresh-fit
    group, or entirely new fresh-fit groups after the old ones). Under that
    shape the restored round-scan carry stays valid: the ensemble state is
    org-independent, old group params zero-pad cleanly along the org axis,
    and joiners enter with zero weight history. Returns a reason string
    naming the first violation otherwise.

    Deep-Model-Sharing groups cannot grow: their extractor/head carry is
    shaped by the member count, so a joiner would invalidate the restored
    state. New orgs must occupy positions >= the fitted org count (old
    positions are the carry's coordinates) with org ids disjoint from the
    fitted ids (ids seed the per-org RNG legs)."""
    mine = plan_to_manifest(plan, model_spec, loss_spec)["groups"]
    theirs = manifest["groups"]
    m_old = sum(len(g["org_ids"]) for g in theirs)
    m_new = sum(len(g["org_ids"]) for g in mine)
    if m_new <= m_old:
        return (f"not a growth: artifact has {m_old} organization(s), "
                f"the supplied set has {m_new}")
    if len(mine) < len(theirs):
        return (f"artifact plan has {len(theirs)} group(s), the supplied "
                f"organizations plan into only {len(mine)}")
    old_ids = {i for g in theirs for i in g["org_ids"]}
    for gi, b in enumerate(theirs):
        a = mine[gi]
        for field_ in ("model", "local_loss", "noise_sigma", "dms"):
            if a[field_] != b[field_]:
                return (f"group {gi} {field_} mismatch: artifact has "
                        f"{b[field_]!r}, the supplied organizations have "
                        f"{a[field_]!r}")
        k = len(b["org_ids"])
        if (a["indices"][:k] != b["indices"]
                or a["org_ids"][:k] != b["org_ids"]):
            return (f"group {gi} does not keep the artifact's members as a "
                    f"prefix: artifact has indices {b['indices']!r} / ids "
                    f"{b['org_ids']!r}, the supplied organizations have "
                    f"{a['indices']!r} / {a['org_ids']!r}")
        if len(a["org_ids"]) > k:
            if b["dms"]:
                return (f"group {gi} uses Deep Model Sharing and cannot "
                        f"grow: its shared extractor/head carry is shaped "
                        f"by the fitted member count")
            bad_pos = [i for i in a["indices"][k:] if i < m_old]
            if bad_pos:
                return (f"group {gi} inserts joiner(s) at fitted org "
                        f"position(s) {bad_pos} (< {m_old}); joiners must "
                        f"occupy new positions at the tail of the org list")
            clash = [i for i in a["org_ids"][k:] if i in old_ids]
            if clash:
                return (f"group {gi} joiner org id(s) {clash} collide with "
                        f"fitted org ids (ids seed the per-org RNG legs and "
                        f"must be unique)")
    for gi in range(len(theirs), len(mine)):
        a = mine[gi]
        if a["dms"]:
            return (f"new group {gi} uses Deep Model Sharing; joining orgs "
                    f"must fresh-fit (DMS needs the full round history)")
        bad_pos = [i for i in a["indices"] if i < m_old]
        if bad_pos:
            return (f"new group {gi} claims fitted org position(s) "
                    f"{bad_pos} (< {m_old}); joiners must occupy new "
                    f"positions at the tail of the org list")
        clash = [i for i in a["org_ids"] if i in old_ids]
        if clash:
            return (f"new group {gi} org id(s) {clash} collide with fitted "
                    f"org ids (ids seed the per-org RNG legs and must be "
                    f"unique)")
    return None


def plan_lm_orgs(orgs: Sequence[Any]) -> ExecutionPlan:
    """The same grouping for LM-scale organizations (``core.gal_lm``):
    groups keyed by (architecture config, local lr). The fused LM path
    additionally requires a single group — ``fit_lm`` raises with
    ``plan.describe()`` otherwise."""
    if not orgs:
        return ExecutionPlan((), reason="no organizations to plan")
    reason = None
    for org in orgs:
        if org.params is None or org._train_step is None:
            reason = (f"LM organization {org.index} is not initialized "
                      f"(call .init(rng) first)")
            break
    keys: List[tuple] = []
    members: List[List[int]] = []
    for i, org in enumerate(orgs):
        k = (org.cfg, org.lr)
        for gi, existing in enumerate(keys):
            if existing == k:
                members[gi].append(i)
                break
        else:
            keys.append(k)
            members.append([i])
    groups = tuple(
        OrgGroup(indices=tuple(idx),
                 org_ids=tuple(int(orgs[i].index) for i in idx),
                 model=orgs[idx[0]].cfg, local_loss=None)
        for idx in members
    )
    return ExecutionPlan(groups=groups, reason=reason)
