"""Dynamic-membership schedules: org dropout, stragglers, mid-fit joins.

A membership schedule is a boolean ``(rounds, M)`` matrix: row t lists the
orgs that show up for assistance round t. The compiled engines thread each
row through the round step as scan inputs — an absent org is masked out of
the step-4 weight fit (exact zero weight, zero gradient), contributes
nothing to the ensemble direction, and disappears from that round's
communication ledger. Everything here is host-side numpy: schedules are
static per fit, so validation and fault injection happen once, before
tracing.

Two sources compose (logical AND):

* an explicit ``gal.fit(membership=...)`` schedule — the deterministic
  "org j drops at round t / joins at round t0" story; and
* ``GALConfig.straggler_sim`` — seeded iid per-(round, org) dropout fault
  injection for robustness testing, with a guarantee that no round ever
  goes empty (the org with the luckiest draw is kept).
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def straggler_schedule(rounds: int, m: int, rate: float, seed: int = 0
                       ) -> np.ndarray:
    """Seeded iid dropout: each (round, org) cell is absent with
    probability ``rate``. Deterministic in (rounds, m, rate, seed) — the
    same config resumes onto the same schedule. Rounds where every org
    straggled are repaired by keeping the org with the largest uniform
    draw, so a fit can never face an empty round."""
    if not 0.0 <= rate < 1.0:
        raise ValueError(f"straggler_sim must be in [0, 1), got {rate}")
    u = np.random.default_rng(seed).random((rounds, m))
    live = u >= rate
    for t in range(rounds):
        if not live[t].any():
            live[t, int(np.argmax(u[t]))] = True
    return live


def resolve_membership(membership, straggler_sim: Optional[float],
                       straggler_seed: int, rounds: int, m: int
                       ) -> Optional[np.ndarray]:
    """Combine the explicit schedule and the straggler simulator into one
    validated bool (rounds, M) matrix, or None when every org attends every
    round (the engines then skip membership bookkeeping entirely)."""
    sched = None
    if membership is not None:
        sched = np.asarray(membership)
        if sched.shape != (rounds, m):
            raise ValueError(
                f"membership schedule must have shape (rounds, M) = "
                f"({rounds}, {m}), got {sched.shape}")
        if sched.dtype != np.bool_:
            vals = np.unique(sched)
            if not np.isin(vals, (0, 1)).all():
                raise ValueError(
                    "membership schedule entries must be boolean / 0-1, "
                    f"got values {vals}")
            sched = sched.astype(bool)
        sched = sched.copy()
    if straggler_sim is not None and straggler_sim > 0.0:
        strag = straggler_schedule(rounds, m, straggler_sim, straggler_seed)
        sched = strag if sched is None else (sched & strag)
    if sched is None:
        return None
    empty = np.flatnonzero(~sched.any(axis=1))
    if empty.size:
        raise ValueError(
            "membership schedule has no live org in round(s) "
            f"{empty.tolist()}; every assistance round needs at least one "
            "participant")
    return sched


def membership_comm_ledger(sched: np.ndarray, n: int, k: int,
                           eval_ns=(),
                           resid_dtype_bytes: int | None = None) -> tuple:
    """Per-round (broadcast, gather) byte lists under a membership
    schedule: only the live orgs of round t receive the residual and ship
    fitted values back, so a masked round's ledger equals the reduced org
    set's ledger exactly, and an all-live round's equals the static one.
    ``resid_dtype_bytes`` is the on-the-wire residual width (2 under
    ``residual_dtype="bf16"``), threaded through to ``gal_round_bytes``."""
    from repro.core.protocol_sim import gal_round_bytes
    bcast, gather = [], []
    for row in np.asarray(sched, bool):
        b, g = gal_round_bytes(n, k, int(row.sum()), eval_ns,
                               resid_dtype_bytes=resid_dtype_bytes)
        bcast.append(b)
        gather.append(g)
    return bcast, gather
