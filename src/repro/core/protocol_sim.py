"""Communication/computation accounting for the GAL protocol (paper Table 14).

Counts the bytes and rounds actually exchanged by Algorithm 1 vs sequential AL
under identical ensemble sizes, and maps the protocol's collectives onto mesh
axes for the distributed runtime:

  residual broadcast  r^t (N x K)        Alice -> M-1 orgs    per round
  fitted values       f_m^t(x_m) (N x K) each org -> Alice    per round
  prediction stage    f_m^t(x_m*)        each org -> Alice    per round

GAL runs orgs in parallel (1 communication round / assistance round); AL
serializes them (M communication rounds per sweep).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ProtocolCost:
    method: str
    orgs: int
    ensemble_members: int
    comm_rounds: int           # synchronization points on the wire
    bytes_broadcast: int       # Alice -> orgs
    bytes_gathered: int        # orgs -> Alice
    sequential_fits: int       # wall-clock critical-path local fits
    model_memories: int        # live model copies (DMS saves T x)

    @property
    def bytes_total(self) -> int:
        return self.bytes_broadcast + self.bytes_gathered


def gal_round_bytes(n: int, k: int, m: int, eval_ns=(),
                    dtype_bytes: int = 4,
                    resid_dtype_bytes: int | None = None) -> tuple:
    """Bytes crossing org boundaries in ONE assistance round, Table-14
    convention: Alice ships the privatized residual to the other M-1 orgs;
    all M orgs — Alice included — ship their fitted values back for the
    train set AND for each eval prediction stage (``eval_ns`` lists the
    eval-set row counts). Returns ``(broadcast, gathered)`` as exact ints.

    ``resid_dtype_bytes`` is the on-the-wire width of the residual
    broadcast alone (``GALConfig(residual_dtype="bf16")`` casts it to 2
    bytes before it leaves Alice); the gathered fitted values always travel
    at ``dtype_bytes``. Defaults to ``dtype_bytes`` — the uncompressed
    protocol.

    This is the ONE source of the engines' per-round communication ledger
    (``history["comm_broadcast_bytes"/"comm_gather_bytes"]``): the
    org-sharded engine's numbers come from the same static collective
    operand shapes, and the scan / grouped / Python engines simulate the
    identical wire protocol, so the ledger is engine-independent."""
    if resid_dtype_bytes is None:
        resid_dtype_bytes = dtype_bytes
    broadcast = (m - 1) * n * k * resid_dtype_bytes
    gathered = m * n * k * dtype_bytes + sum(m * int(ne) * k * dtype_bytes
                                             for ne in eval_ns)
    return broadcast, gathered


def gal_model_memories(rounds: int, dms_flags, membership=None) -> list:
    """Per-round live model copies (paper Table 14's computation-space row,
    Sec. 5 Deep Model Sharing): after round t+1, a fresh-fit organization
    holds t+1 full models (one per round) while a DMS organization holds
    ONE shared extractor — its per-round heads are the lightweight Tx
    saving. ``dms_flags`` is the per-org DMS flag list in org order.

    ``membership`` is an optional bool (rounds, M) attendance schedule
    (see core/membership.py): a fresh-fit org only accrues a model in the
    rounds it attends, and a DMS org's shared extractor exists from its
    first attended round onward. An org that never shows up holds nothing,
    so a fully-masked org leaves the ledger identical to the reduced org
    set's — while an all-live schedule reproduces the static counts.

    This is the one source of ``history["model_memories"]`` on every
    engine; for an all-DMS (resp. no-DMS) org set the final entry equals
    ``gal_cost(..., dms=True).model_memories`` (resp. ``dms=False``)."""
    if membership is None:
        m_dms = sum(1 for f in dms_flags if f)
        m_fresh = len(dms_flags) - m_dms
        return [m_dms + (t + 1) * m_fresh for t in range(rounds)]
    out = []
    attended = [0] * len(dms_flags)
    for t in range(rounds):
        for j, flag in enumerate(dms_flags):
            if membership[t][j]:
                attended[j] += 1
        out.append(sum((1 if dms else att) if att else 0
                       for dms, att in zip(dms_flags, attended)))
    return out


def gal_cost(n: int, k: int, m: int, rounds: int, dtype_bytes: int = 4,
             dms: bool = False) -> ProtocolCost:
    resid = n * k * dtype_bytes
    return ProtocolCost(
        method="GAL_DMS" if dms else "GAL",
        orgs=m,
        ensemble_members=rounds * m,
        comm_rounds=rounds,                       # orgs fit in parallel
        bytes_broadcast=rounds * (m - 1) * resid, # Alice already holds r
        bytes_gathered=rounds * m * resid,
        sequential_fits=rounds,                   # critical path: 1 fit/round
        model_memories=m if dms else rounds * m,
    )


def al_cost(n: int, k: int, m: int, rounds: int, dtype_bytes: int = 4
            ) -> ProtocolCost:
    """AL reaching the same ensemble size needs rounds*m sequential fits."""
    resid = n * k * dtype_bytes
    steps = rounds * m
    return ProtocolCost(
        method="AL",
        orgs=m,
        ensemble_members=steps,
        comm_rounds=steps,                        # strictly sequential
        bytes_broadcast=steps * resid,
        bytes_gathered=steps * resid,
        sequential_fits=steps,                    # critical path: every fit
        model_memories=steps,
    )


def complexity_table(n: int, k: int, m: int, rounds: int):
    """Reproduces paper Table 14's 1x / Mx / Tx relations, with real byte
    counts for the given problem size."""
    g = gal_cost(n, k, m, rounds)
    d = gal_cost(n, k, m, rounds, dms=True)
    a = al_cost(n, k, m, rounds)
    rows = []
    for c in (a, g, d):
        rows.append({
            "method": c.method,
            "computation_time_x": c.sequential_fits / g.sequential_fits,
            "computation_space_x": c.model_memories / d.model_memories,
            "communication_rounds_x": c.comm_rounds / g.comm_rounds,
            "bytes_total": c.bytes_total,
        })
    return rows
