"""Organization abstraction (paper Sec. 3.1-3.2).

An Organization privately owns: a vertical feature slice x_m, a model class
F_m (any zoo model or a sequence-model adapter), and a local regression loss
ell_m used to fit the broadcast pseudo-residuals. Nothing here is ever read by
the GAL engine except the *fitted values* f_m^t(x_m) — matching the paper's
"no sharing of data, models, objective functions" contract.

Deep Model Sharing (paper Sec. 4.2): instead of a fresh model per round, the
organization keeps one shared feature extractor f_{m,e} and a per-round output
head f_{m,o}^t, refit each round against the stacked residual history r^{1:t}.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.core.losses import lq_loss
from repro.optim.optimizers import adam, apply_updates


@dataclass
class Organization:
    index: int
    x_train: Any                       # private vertical slice (N, d_m) or images
    model: Any                         # zoo model (duck-typed)
    local_loss: Callable = field(default_factory=lambda: lq_loss(2.0))
    noise_sigma: float = 0.0           # ablation: noisy org outputs (Table 6)
    dms: bool = False                  # Deep Model Sharing
    # --- private state (never read by the engine) ---
    _round_params: List[Any] = field(default_factory=list)
    _dms_extractor: Any = None
    _dms_heads: List[Any] = field(default_factory=list)
    _residual_history: List[jnp.ndarray] = field(default_factory=list)
    _live_slots: List[bool] = field(default_factory=list)

    # ------------------------------------------------------------------ fit
    def reset_round_state(self) -> None:
        """Clear all per-round fit state so this Organization can be fit
        again from scratch.

        Every engine (``gal.fit``, ``al.fit``) calls this at the top of a
        fit: without it a second fit *appends* to ``_round_params`` /
        ``_dms_heads``, so ``predict_round(t, ...)`` silently reads round t
        of the FIRST fit — corrupting rounds sweeps and GAL-after-AL
        comparisons. The DMS extractor is reset too, so refitting with the
        same rng reproduces a fresh fit exactly.

        Consequence: refitting INVALIDATES earlier python-engine results
        built on the same Organization objects — their ``predict`` reads
        this live state via ``predict_round``. Keep the old result usable
        by fitting fresh orgs (``make_orgs``) instead. Fast-path results
        (scan/shard) own their stacked per-round params and stay valid."""
        self._round_params = []
        self._dms_extractor = None
        self._dms_heads = []
        self._residual_history = []
        self._live_slots = []

    def fit_round(self, rng: jax.Array, residual: jnp.ndarray,
                  live: bool = True) -> jnp.ndarray:
        """Fit this round's local model to the broadcast pseudo-residual and
        return the fitted values f_m^t(x_m) on the training set.

        ``live`` is this org's membership bit for the round
        (``core.membership``): the caller still invokes ``fit_round`` every
        round so the params list and RNG chain stay round-aligned, but an
        absent round is DEAD downstream — the engine pins its assistance
        weight to exactly 0.0, so the fresh-fit values returned here never
        reach the ensemble. A Deep-Model-Sharing org additionally skips the
        joint refit when absent: round ``t`` keeps a zero head forever (the
        dead slot is masked out of every later refit objective) while the
        broadcast residual still enters the history buffer."""
        if self.dms:
            fitted = self._fit_round_dms(rng, residual, live)
            if not live:
                return fitted
        else:
            params = self.model.fit(rng, self.x_train, residual, self.local_loss)
            self._round_params.append(params)
            fitted = self.model.apply(params, self.x_train)
        if self.noise_sigma > 0.0:
            fitted = fitted + self.noise_sigma * jax.random.normal(
                jax.random.fold_in(rng, 777), fitted.shape
            )
        return fitted

    def _fit_round_dms(self, rng: jax.Array, residual: jnp.ndarray,
                       live: bool = True) -> jnp.ndarray:
        """Jointly refit shared extractor + the attended per-round heads on
        the attended slice of r^{1:t} (all of it when every round was
        attended — the membership-free objective unchanged)."""
        self._residual_history.append(residual)
        t = len(self._residual_history)
        k_out = residual.shape[-1]
        if self._dms_extractor is None:
            # init at the FIRST round regardless of attendance — the fused
            # engine builds the extractor stack from round 0's org keys
            # before the scan, so a late joiner still draws round 0's init
            full = self.model.init(rng, self.x_train, k_out)
            self._dms_extractor = {k: v for k, v in full.items() if k != "head"}
        if not live:
            # dead slot: zero head, no refit, nothing for the ensemble
            spec = jax.eval_shape(
                lambda kk: self.model.init_head(kk, k_out),
                jax.random.PRNGKey(0))
            self._dms_heads.append(jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), spec))
            self._live_slots.append(False)
            return jnp.zeros_like(residual)
        self._dms_heads.append(self.model.init_head(jax.random.fold_in(rng, t), k_out))
        self._live_slots.append(True)

        live_idx = [s for s, lv in enumerate(self._live_slots) if lv]
        extractor = self._dms_extractor
        heads = [self._dms_heads[s] for s in live_idx]
        model, x, loss = self.model, self.x_train, self.local_loss
        r_stack = jnp.stack([self._residual_history[s] for s in live_idx])

        def objective(params):
            # mean over rounds of the per-round local loss — the per-slot
            # form lets arbitrary (non-ell_q) losses see the (N, K) shapes
            # they were written for, and is the exact objective the traced
            # DMS path in repro.core.engine masks over its (T, ...) buffers
            ext, hds = params
            feats = model.features({**ext, "head": None}, x)
            preds = jnp.stack([model.apply_head(h, feats) for h in hds])  # (t,N,K)
            return jnp.mean(jax.vmap(loss)(r_stack, preds))

        params = (extractor, heads)
        opt = adam(getattr(model, "lr", 1e-3))
        state = opt.init(params)
        epochs = getattr(model, "epochs", 100)

        @jax.jit
        def step(carry, _):
            p, s = carry
            g = jax.grad(objective)(p)
            upd, s = opt.update(g, s, p)
            return (apply_updates(p, upd), s), None

        (params, _), _ = jax.lax.scan(step, (params, state), None, length=epochs)
        self._dms_extractor, new_heads = params
        for s, h in zip(live_idx, new_heads):
            self._dms_heads[s] = h
        feats = model.features({**self._dms_extractor, "head": None}, x)
        return model.apply_head(self._dms_heads[-1], feats)

    # ------------------------------------------------------------- predict
    def predict_round(self, t: int, x: jnp.ndarray) -> jnp.ndarray:
        """Prediction-stage output f_m^t(x_m*) for round t (0-based)."""
        if self.dms:
            feats = self.model.features({**self._dms_extractor, "head": None}, x)
            out = self.model.apply_head(self._dms_heads[t], feats)
        else:
            out = self.model.apply(self._round_params[t], x)
        if self.noise_sigma > 0.0:
            # Table 6 injects noise during learning AND prediction. The key
            # is derived with fold_in (NOT Python hash) so it is traceable
            # under jit/vmap with a traced round index t, and every engine —
            # this Python path, the grouped fused engine, the stacked
            # prediction path — draws the identical noise for (org, round).
            key = jax.random.fold_in(jax.random.PRNGKey(self.index), t)
            out = out + self.noise_sigma * jax.random.normal(key, out.shape)
        return out

    @property
    def n_rounds_fit(self) -> int:
        return len(self._dms_heads) if self.dms else len(self._round_params)

    @property
    def scan_safe(self) -> bool:
        """True when this org can join a compiled engine group: pure-jnp
        (``scan_safe``) model fits. Neither output noise nor Deep Model
        Sharing blocks compilation any more — noise keys are
        ``fold_in``-derived and traceable, and the DMS extractor/head state
        rides the scan carry as a stacked ``(T, ...)`` head buffer (see
        ``repro.core.engine``); the planner (``repro.core.plan``) groups
        noisy orgs by sigma and DMS orgs by extractor signature, provided
        the model exposes ``features``/``init_head``/``apply_head``."""
        from repro.core.plan import dms_traceable
        if self.dms:
            return dms_traceable(self.model)
        return getattr(self.model, "scan_safe", False)


def make_orgs(xs, model_factory, local_losses=None, dms=False,
              noise_sigmas=None) -> List[Organization]:
    """Build M organizations from vertical slices ``xs`` (list of arrays).

    ``model_factory`` is either one zoo model (shared class, private params) or
    a list of per-org models — the paper's model-autonomy setting (GB-SVM mix).
    ``dms`` is one flag for every org or a per-org sequence (a DMS +
    fresh-fit mix, each side planned into its own compiled group).
    """
    m = len(xs)
    models = model_factory if isinstance(model_factory, (list, tuple)) \
        else [model_factory] * m
    losses = local_losses if local_losses is not None else [lq_loss(2.0)] * m
    if callable(losses):
        losses = [losses] * m
    sigmas = noise_sigmas if noise_sigmas is not None else [0.0] * m
    dms_flags = list(dms) if isinstance(dms, (list, tuple)) else [dms] * m
    return [
        Organization(index=i, x_train=xs[i], model=models[i],
                     local_loss=losses[i], dms=bool(dms_flags[i]),
                     noise_sigma=sigmas[i])
        for i in range(m)
    ]
