"""Gradient assistance weights (paper Alg. 1 + Appendix D.4.2).

Alice solves  w-hat = argmin_{w in simplex}  E_N ell_1(r, sum_m w_m f_m)
with the simplex enforced by a softmax parametrization and optimized with
Adam (paper Table 9: lr 1e-1, weight decay 5e-4, 100 epochs).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim.optimizers import adam, apply_updates


def fit_weights(rng: jax.Array, residual: jnp.ndarray, preds: jnp.ndarray,
                loss: Callable, epochs: int = 100, lr: float = 0.1,
                weight_decay: float = 5e-4) -> jnp.ndarray:
    """preds: (M, N, K) stacked org outputs; returns w in the M-simplex.

    Pure lax-scan Adam: traces once inside the fused engine's round step.
    theta is pinned to f32 so the simplex softmax stays full precision even
    when the org outputs arrive in a lower dtype (LM-scale logits).

    ``rng`` seeds the softmax logits theta — a small jitter around the
    uniform-weights start. Every engine threads ``fold_in(k_round, 29)``
    here, so the round key fully determines the weight fit (the step-4 leg
    of the engines' RNG-discipline parity; pinned by
    tests/test_weights.py)."""
    m = preds.shape[0]
    theta0 = 0.01 * jax.random.normal(rng, (m,), jnp.float32)

    def objective(theta):
        w = jax.nn.softmax(theta)
        combined = jnp.einsum("m,mnk->nk", w, preds)
        return loss(residual, combined)

    opt = adam(lr, weight_decay=weight_decay)
    state = opt.init(theta0)

    def step(carry, _):
        theta, st = carry
        g = jax.grad(objective)(theta)
        upd, st = opt.update(g, st, theta)
        return (apply_updates(theta, upd), st), None

    (theta, _), _ = jax.lax.scan(step, (theta0, state), None, length=epochs)
    return jax.nn.softmax(theta)


def uniform_weights(m: int) -> jnp.ndarray:
    """Direct-average ablation (Table 6, 'Weight = x')."""
    return jnp.full((m,), 1.0 / m)
