"""Gradient assistance weights (paper Alg. 1 + Appendix D.4.2).

Alice solves  w-hat = argmin_{w in simplex}  E_N ell_1(r, sum_m w_m f_m)
with the simplex enforced by a softmax parametrization and optimized with
Adam (paper Table 9: lr 1e-1, weight decay 5e-4, 100 epochs).

Dynamic membership (org dropout / stragglers / mid-fit joins) enters here
as a per-org ``mask``: absent orgs are pinned to an EXACT zero weight at
every Adam step and receive zero gradient, so the live orgs' optimization
trajectory is identical to solving the reduced problem over the live set
alone. Combined with per-org-id theta seeding (``org_ids``), this is what
makes a masked fit bitwise-equal to a from-scratch fit of the reduced org
set (the counterfactual parity pinned by tests/test_membership.py).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim.optimizers import adam, apply_updates


def _masked_softmax(theta: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """softmax over the live entries only; masked entries are EXACT zeros.

    The shift is a stop_gradient max over live entries, so live thetas see
    the same gradients they would in a reduced-size softmax, and masked
    thetas see exactly zero gradient (their ``where`` branch is constant).
    With a single live entry the result is exp(0)/exp(0) == 1.0 exactly,
    matching ``uniform_weights(1)`` bitwise.
    """
    neg = jnp.asarray(-jnp.inf, theta.dtype)
    shift = jax.lax.stop_gradient(
        jnp.max(jnp.where(mask, theta, neg)))
    e = jnp.where(mask, jnp.exp(theta - shift), 0.0)
    return e / jnp.sum(e)


def fit_weights(rng: jax.Array, residual: jnp.ndarray, preds: jnp.ndarray,
                loss: Callable, epochs: int = 100, lr: float = 0.1,
                weight_decay: float = 5e-4,
                mask: Optional[jnp.ndarray] = None,
                org_ids: Optional[jnp.ndarray] = None,
                m: Optional[int] = None,
                combine_fn: Optional[Callable] = None,
                objective_fn: Optional[Callable] = None,
                grad_axes: tuple = ()) -> jnp.ndarray:
    """preds: (M, N, K) stacked org outputs; returns w in the M-simplex.

    Pure lax-scan Adam: traces once inside the fused engine's round step.
    theta is pinned to f32 so the simplex softmax stays full precision even
    when the org outputs arrive in a lower dtype (LM-scale logits).

    ``rng`` seeds the softmax logits theta — a small jitter around the
    uniform-weights start. Every engine threads ``fold_in(k_round, 29)``
    here, so the round key fully determines the weight fit (the step-4 leg
    of the engines' RNG-discipline parity; pinned by
    tests/test_weights.py). Each org's logit is drawn from
    ``fold_in(rng, org_id)`` — keyed by org IDENTITY, not position — so a
    reduced org set draws the same per-org jitter as the full set.

    ``mask`` is the (M,) membership row for this round (None = all live):
    masked orgs get weight exactly 0.0 and contribute nothing — not even
    fp association noise — to the objective or to any live org's gradient.

    Distributed form (the block-sharded engine): ``combine_fn(w)`` replaces
    the replicated einsum with the caller's own combination of the FULL
    (M,)-simplex ``w`` against block-local predictions — typically a
    ``dynamic_slice`` of ``w`` at the device's block offset, a local
    einsum, and a psum over the "org" mesh axis.  Because the slice's
    gradient transpose scatters into zeros, each device's theta-gradient is
    block-local only, so the per-step gradient MUST be summed over
    ``grad_axes`` (mesh axis names) to recover the replicated trajectory;
    ``m`` pins the simplex size when ``preds`` no longer carries it.

    ``objective_fn(w)`` replaces the loss evaluation entirely (it takes
    precedence over ``combine_fn``): the caller supplies a scalar whose
    gradient, summed over ``grad_axes``, equals the replicated objective's.
    This is how the block-sharded engine runs the quadratic (alice_q == 2)
    fit on per-block Gram statistics — O(B*M) per Adam epoch with a single
    (M,) collective, instead of re-materializing the (N, K) combination
    every epoch. The per-device VALUE may be a partial sum (Adam only ever
    consumes the gradient). The default arguments leave the replicated
    path untouched.
    """
    if m is None:
        m = preds.shape[0]
    if org_ids is None:
        org_ids = jnp.arange(m, dtype=jnp.uint32)
    if mask is None:
        mask = jnp.ones((m,), bool)
    keys = jax.vmap(lambda i: jax.random.fold_in(rng, i))(org_ids)
    theta0 = 0.01 * jax.vmap(
        lambda k: jax.random.normal(k, (), jnp.float32))(keys)

    def objective(theta):
        w = _masked_softmax(theta, mask)
        if objective_fn is not None:
            return objective_fn(w)
        if combine_fn is not None:
            combined = combine_fn(w)
        else:
            combined = jnp.einsum("m,mnk->nk", w, preds)
        return loss(residual, combined)

    opt = adam(lr, weight_decay=weight_decay)
    state = opt.init(theta0)

    def step(carry, _):
        theta, st = carry
        g = jax.grad(objective)(theta)
        for ax in grad_axes:
            g = jax.lax.psum(g, ax)
        upd, st = opt.update(g, st, theta)
        return (apply_updates(theta, upd), st), None

    (theta, _), _ = jax.lax.scan(step, (theta0, state), None, length=epochs)
    return _masked_softmax(theta, mask)


def uniform_weights(m: int, mask: Optional[jnp.ndarray] = None
                    ) -> jnp.ndarray:
    """Direct-average ablation (Table 6, 'Weight = x'); with a membership
    ``mask``, the average renormalizes over the live orgs (1/|live| each,
    exact zeros elsewhere)."""
    if mask is None:
        return jnp.full((m,), 1.0 / m)
    maskf = mask.astype(jnp.float32)
    return maskf / jnp.sum(maskf)
