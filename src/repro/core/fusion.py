"""Centralized data-fusion baselines (paper Sec. 4: 'Interm' and 'Late').

Both require label sharing and synchronous end-to-end training — they are the
*centralized upper bounds* GAL is compared against, not decentralized methods.

  Late   : F(x) = sum_m f_m(x_m), all f_m trained jointly on L1.
  Interm : h = sum_m extract_m(x_m); F(x) = head(h) — needs feature models
           (MLP/CNN/GRU), matching the paper's note that Interm is deep-only.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.core.losses import Loss
from repro.optim.optimizers import adam, apply_updates


@dataclass
class FusionResult:
    mode: str
    models: list
    params: list
    head: object | None

    def predict(self, xs: Sequence[jnp.ndarray]) -> jnp.ndarray:
        if self.mode == "late":
            return sum(m.apply(p, x) for m, p, x in zip(self.models, self.params, xs))
        feats = sum(m.features(p, x) for m, p, x in zip(self.models, self.params, xs))
        return self.models[0].apply_head(self.head, feats)


def _train(objective, params, epochs: int, lr: float):
    opt = adam(lr)
    state = opt.init(params)

    @jax.jit
    def step(carry, _):
        p, s = carry
        g = jax.grad(objective)(p)
        upd, s = opt.update(g, s, p)
        return (apply_updates(p, upd), s), None

    (params, _), _ = jax.lax.scan(step, (params, state), None, length=epochs)
    return params


def fit_late(rng: jax.Array, xs: Sequence[jnp.ndarray], y: jnp.ndarray,
             loss: Loss, models, epochs: int = 200, lr: float = 1e-2
             ) -> FusionResult:
    models = list(models) if isinstance(models, (list, tuple)) \
        else [models] * len(xs)
    k = y.shape[-1]
    keys = jax.random.split(rng, len(xs))
    params = [m.init(keys[i], xs[i], k) for i, m in enumerate(models)]

    def objective(ps):
        f = sum(m.apply(p, x) for m, p, x in zip(models, ps, xs))
        return loss(y, f)

    params = _train(objective, params, epochs, lr)
    return FusionResult("late", models, params, None)


def fit_interm(rng: jax.Array, xs: Sequence[jnp.ndarray], y: jnp.ndarray,
               loss: Loss, models, epochs: int = 200, lr: float = 1e-2
               ) -> FusionResult:
    models = list(models) if isinstance(models, (list, tuple)) \
        else [models] * len(xs)
    k = y.shape[-1]
    keys = jax.random.split(rng, len(xs) + 1)
    params = [m.init(keys[i], xs[i], k) for i, m in enumerate(models)]
    head = models[0].init_head(keys[-1], k)

    def objective(all_params):
        ps, hd = all_params
        feats = sum(m.features(p, x) for m, p, x in zip(models, ps, xs))
        return loss(y, models[0].apply_head(hd, feats))

    params, head = _train(objective, (params, head), epochs, lr)
    return FusionResult("interm", models, params, head)
