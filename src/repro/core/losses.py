"""Overarching losses L1 and local regression losses ell_m (paper Sec. 3.2).

Conventions (matching gradient boosting, to which GAL reduces for M=1):
  * F lives in *link space*: raw logits for classification, raw output for
    regression. y is one-hot (N, K) for K-class tasks, (N, 1) for regression
    and binary tasks.
  * ``residual(y, F)`` is the per-sample pseudo-residual
        r = -dL(y, F)/dF     (no 1/N factor; the N-mean lives in the loss)
    which is the tensor Alice broadcasts each assistance round.
  * ``init_prediction(y)`` gives F^0: E_N(y) mapped to link space (the paper's
    deterministic unbiased initializer, Appendix A.1).

Local losses ell_q(r, f) = mean |r - f|^q  (paper Table 4, q in {1,1.5,2,4}).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.utils.registry import Registry

LOSSES: Registry = Registry("loss")


@dataclass(frozen=True)
class Loss:
    name: str

    def __call__(self, y, f):  # mean scalar loss
        raise NotImplementedError

    def residual(self, y, f):  # per-sample -dL/dF
        # generic fallback: autodiff of the summed loss
        return -jax.grad(lambda ff: jnp.sum(self.per_sample(y, ff)))(f)

    def per_sample(self, y, f):
        raise NotImplementedError

    def init_prediction(self, y):
        raise NotImplementedError


@LOSSES.register("mse")
@dataclass(frozen=True)
class MSELoss(Loss):
    name: str = "mse"

    def per_sample(self, y, f):
        return 0.5 * jnp.sum(jnp.square(y - f), axis=-1)

    def __call__(self, y, f):
        return jnp.mean(self.per_sample(y, f))

    def residual(self, y, f):
        return y - f

    def init_prediction(self, y):
        return jnp.mean(y, axis=0, keepdims=True)


@LOSSES.register("mae")
@dataclass(frozen=True)
class MAELoss(Loss):
    """Mean absolute deviation (the paper's regression metric and an L1 choice)."""
    name: str = "mae"

    def per_sample(self, y, f):
        return jnp.sum(jnp.abs(y - f), axis=-1)

    def __call__(self, y, f):
        return jnp.mean(self.per_sample(y, f))

    def residual(self, y, f):
        return jnp.sign(y - f)

    def init_prediction(self, y):
        return jnp.median(y, axis=0, keepdims=True)


@LOSSES.register("xent")
@dataclass(frozen=True)
class CrossEntropyLoss(Loss):
    """K-class cross entropy on logits; r = y - softmax(F) (Friedman multiclass)."""
    name: str = "xent"

    def per_sample(self, y, f):
        return -jnp.sum(y * jax.nn.log_softmax(f, axis=-1), axis=-1)

    def __call__(self, y, f):
        return jnp.mean(self.per_sample(y, f))

    def residual(self, y, f):
        return y - jax.nn.softmax(f, axis=-1)

    def init_prediction(self, y):
        prior = jnp.clip(jnp.mean(y, axis=0, keepdims=True), 1e-6, 1.0)
        return jnp.log(prior)


@LOSSES.register("bce")
@dataclass(frozen=True)
class BCELoss(Loss):
    """Binary cross entropy on a single logit (imbalanced tasks, MIMICM-like)."""
    name: str = "bce"

    def per_sample(self, y, f):
        return jnp.sum(
            jnp.maximum(f, 0.0) - f * y + jnp.log1p(jnp.exp(-jnp.abs(f))), axis=-1
        )

    def __call__(self, y, f):
        return jnp.mean(self.per_sample(y, f))

    def residual(self, y, f):
        return y - jax.nn.sigmoid(f)

    def init_prediction(self, y):
        p = jnp.clip(jnp.mean(y, axis=0, keepdims=True), 1e-6, 1 - 1e-6)
        return jnp.log(p / (1 - p))


def lq_loss(q: float):
    """Local regression loss ell_q(r, f) = mean |r - f|^q (paper Table 4)."""
    q = float(q)

    def loss(r, f):
        d = jnp.abs(r - f)
        if q == 2.0:
            return jnp.mean(jnp.square(d))
        if q == 1.0:
            # smooth |.| for stable autodiff at 0
            return jnp.mean(jnp.sqrt(jnp.square(d) + 1e-12))
        return jnp.mean(jnp.power(d + 1e-12, q))

    loss.q = q
    loss.__name__ = f"l{q:g}"
    return loss


def get_loss(name: str) -> Loss:
    cls = LOSSES.get(name)
    return cls() if isinstance(cls, type) else cls
