"""Overarching losses L1 and local regression losses ell_m (paper Sec. 3.2).

Conventions (matching gradient boosting, to which GAL reduces for M=1):
  * F lives in *link space*: raw logits for classification, raw output for
    regression. y is one-hot (N, K) for K-class tasks, (N, 1) for regression
    and binary tasks.
  * ``residual(y, F)`` is the per-sample pseudo-residual
        r = -dL(y, F)/dF     (no 1/N factor; the N-mean lives in the loss)
    which is the tensor Alice broadcasts each assistance round.
  * ``init_prediction(y)`` gives F^0: E_N(y) mapped to link space (the paper's
    deterministic unbiased initializer, Appendix A.1).

Local losses ell_q(r, f) = mean |r - f|^q  (paper Table 4, q in {1,1.5,2,4}).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.utils.registry import Registry

LOSSES: Registry = Registry("loss")


@dataclass(frozen=True)
class Loss:
    name: str

    def __call__(self, y, f):  # mean scalar loss
        raise NotImplementedError

    def residual(self, y, f):  # per-sample -dL/dF
        # generic fallback: autodiff of the summed loss. Pure lax, so a
        # custom Loss subclass that only defines per_sample compiles
        # straight into the fused engines' scanned round step — no Python
        # fallback for autodiff-residual losses.
        return -jax.grad(lambda ff: jnp.sum(self.per_sample(y, ff)))(f)

    def per_sample(self, y, f):
        raise NotImplementedError

    def init_prediction(self, y):
        raise NotImplementedError


def autodiff_residual(loss: Loss, y, f):
    """The generic ``-dL/dF`` fallback of ``Loss.residual``, bypassing any
    closed form the subclass defines. This is the oracle the closed forms
    and the Pallas ``residual_xent`` kernel are validated against
    (``tests/test_kernels.py``), and what a custom loss gets for free
    inside the compiled engines."""
    return Loss.residual(loss, y, f)


# vocab width from which CrossEntropyLoss.residual routes through the fused
# Pallas kernel (kernels/residual_xent.py): below this a second (N, K)
# softmax buffer is cheap; at LM scale the kernel streams vocab tiles
# through VMEM instead of materializing softmax(F) in HBM.
XENT_KERNEL_MIN_CLASSES = 1024
# backends where the automatic route engages. Elsewhere (CPU/GPU) the
# kernel would run in interpret mode — Python-emulated, far slower than the
# closed form — or fail to lower, so the closed form stays the default;
# tests widen this to exercise the dispatch in interpret mode.
XENT_KERNEL_BACKENDS = ("tpu",)


@LOSSES.register("mse")
@dataclass(frozen=True)
class MSELoss(Loss):
    name: str = "mse"

    def per_sample(self, y, f):
        return 0.5 * jnp.sum(jnp.square(y - f), axis=-1)

    def __call__(self, y, f):
        return jnp.mean(self.per_sample(y, f))

    def residual(self, y, f):
        return y - f

    def init_prediction(self, y):
        return jnp.mean(y, axis=0, keepdims=True)


@LOSSES.register("mae")
@dataclass(frozen=True)
class MAELoss(Loss):
    """Mean absolute deviation (the paper's regression metric and an L1 choice)."""
    name: str = "mae"

    def per_sample(self, y, f):
        return jnp.sum(jnp.abs(y - f), axis=-1)

    def __call__(self, y, f):
        return jnp.mean(self.per_sample(y, f))

    def residual(self, y, f):
        return jnp.sign(y - f)

    def init_prediction(self, y):
        return jnp.median(y, axis=0, keepdims=True)


@LOSSES.register("xent")
@dataclass(frozen=True)
class CrossEntropyLoss(Loss):
    """K-class cross entropy on logits; r = y - softmax(F) (Friedman
    multiclass). At LM scale (K >= ``XENT_KERNEL_MIN_CLASSES``, a
    ``XENT_KERNEL_BACKENDS`` backend) the residual routes through the
    fused Pallas kernel ``kernels/residual_xent.py`` automatically — the
    broadcast tensor is GAL's protocol hot path, and the kernel streams
    vocab tiles through VMEM instead of materializing softmax(F) as a
    second (N, K) buffer. The kernel recovers labels via argmax, so the
    route adds the correction term ``y - onehot(argmax(y))`` — exactly
    zero for one-hot y and exactly the smoothing mass for soft targets,
    keeping both conventions exact on every backend."""
    name: str = "xent"

    def per_sample(self, y, f):
        return -jnp.sum(y * jax.nn.log_softmax(f, axis=-1), axis=-1)

    def __call__(self, y, f):
        return jnp.mean(self.per_sample(y, f))

    def residual(self, y, f):
        if (f.ndim == 2 and y.shape == f.shape
                and f.shape[-1] >= XENT_KERNEL_MIN_CLASSES
                and jax.default_backend() in XENT_KERNEL_BACKENDS):
            # static shape+backend gate: trace-safe, picked up inside the
            # fused round scan with no engine involvement. The kernel
            # recovers labels via argmax, so
            #   r = y - softmax
            #     = (onehot(argmax y) - softmax)   <- the kernel
            #     + (y - onehot(argmax y))         <- zero for one-hot y
            # and soft/smoothed targets stay exact too; the correction is
            # a fused elementwise term, no extra softmax buffer.
            from repro.kernels.ops import residual_xent
            labels = jnp.argmax(y, axis=-1)
            hard = jax.nn.one_hot(labels, f.shape[-1], dtype=y.dtype)
            return residual_xent(f, labels) + (y - hard)
        return y - jax.nn.softmax(f, axis=-1)

    def init_prediction(self, y):
        prior = jnp.clip(jnp.mean(y, axis=0, keepdims=True), 1e-6, 1.0)
        return jnp.log(prior)


@LOSSES.register("bce")
@dataclass(frozen=True)
class BCELoss(Loss):
    """Binary cross entropy on a single logit (imbalanced tasks, MIMICM-like)."""
    name: str = "bce"

    def per_sample(self, y, f):
        return jnp.sum(
            jnp.maximum(f, 0.0) - f * y + jnp.log1p(jnp.exp(-jnp.abs(f))), axis=-1
        )

    def __call__(self, y, f):
        return jnp.mean(self.per_sample(y, f))

    def residual(self, y, f):
        return y - jax.nn.sigmoid(f)

    def init_prediction(self, y):
        p = jnp.clip(jnp.mean(y, axis=0, keepdims=True), 1e-6, 1 - 1e-6)
        return jnp.log(p / (1 - p))


def lq_loss(q: float):
    """Local regression loss ell_q(r, f) = mean |r - f|^q (paper Table 4)."""
    q = float(q)

    def loss(r, f):
        d = jnp.abs(r - f)
        if q == 2.0:
            return jnp.mean(jnp.square(d))
        if q == 1.0:
            # smooth |.| for stable autodiff at 0
            return jnp.mean(jnp.sqrt(jnp.square(d) + 1e-12))
        return jnp.mean(jnp.power(d + 1e-12, q))

    loss.q = q
    loss.__name__ = f"l{q:g}"
    return loss


def get_loss(name: str) -> Loss:
    cls = LOSSES.get(name)
    return cls() if isinstance(cls, type) else cls
