"""Privacy enhancement of the broadcast pseudo-residuals (paper Sec. 4.5).

GAL_DP — Laplace mechanism with privacy budget alpha: per-coordinate scale
b = sensitivity / alpha where sensitivity is the empirical column range of the
residual tensor (the quantity actually broadcast).

GAL_IP — Interval Privacy (Ding & Ding, 2022) with 1 interval: a random split
point is drawn per column; each residual reports only the midpoint of the side
it falls on, revealing a single comparison bit instead of the value.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dp_laplace(rng: jax.Array, residual: jnp.ndarray, alpha: float = 1.0) -> jnp.ndarray:
    lo = jnp.min(residual, axis=0, keepdims=True)
    hi = jnp.max(residual, axis=0, keepdims=True)
    sensitivity = jnp.maximum(hi - lo, 1e-8)
    scale = sensitivity / alpha
    u = jax.random.uniform(rng, residual.shape, minval=-0.5 + 1e-6, maxval=0.5 - 1e-6)
    noise = -scale * jnp.sign(u) * jnp.log1p(-2.0 * jnp.abs(u))
    return residual + noise


def ip_interval(rng: jax.Array, residual: jnp.ndarray, n_intervals: int = 1) -> jnp.ndarray:
    """residual: (N, K). Each value reports only the midpoint of its bin;
    bin edges are n_intervals random split points per column."""
    lo = jnp.min(residual, axis=0)                               # (K,)
    hi = jnp.max(residual, axis=0)
    u = jax.random.uniform(rng, (n_intervals,) + lo.shape)
    splits = jnp.sort(lo[None] + u * (hi - lo)[None], axis=0)    # (S, K)
    edges = jnp.concatenate(
        [lo[None], splits, (hi + 1e-6)[None]], axis=0)           # (S+2, K)
    # bin index: count of edges (excluding last) <= value
    idx = jnp.sum(residual[None] >= edges[:-1][:, None, :], axis=0) - 1
    idx = jnp.clip(idx, 0, n_intervals)                          # (N, K)
    left = jnp.take_along_axis(edges, idx, axis=0)
    right = jnp.take_along_axis(edges, idx + 1, axis=0)
    return 0.5 * (left + right)


def apply_privacy(rng: jax.Array, residual: jnp.ndarray, mechanism: str | None,
                  alpha: float = 1.0, n_intervals: int = 1) -> jnp.ndarray:
    if mechanism in (None, "none"):
        return residual
    if mechanism == "dp":
        return dp_laplace(rng, residual, alpha=alpha)
    if mechanism == "ip":
        return ip_interval(rng, residual, n_intervals=n_intervals)
    raise ValueError(f"unknown privacy mechanism {mechanism!r}")
