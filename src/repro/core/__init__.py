"""GAL core: the paper's contribution as a composable JAX module."""
from repro.core.losses import (
    Loss, MSELoss, MAELoss, CrossEntropyLoss, BCELoss, lq_loss, get_loss,
)
from repro.core.organizations import Organization, make_orgs
from repro.core.gal import GALConfig, GALResult, fit
from repro.core import al, boosting, fusion, privacy, protocol_sim, weights
from repro.core import gal_lm  # noqa: F401
