"""'Joint' oracle baseline: classical Gradient Boosting (paper Sec. 4).

GAL reduces to Friedman's gradient boosting when M = 1 — the 'Joint' case is
GAL run with a single organization holding the *concatenated* features. This
module is the thin wrapper that makes this reduction explicit (and is used by
tests asserting the reduction).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import gal
from repro.core.gal import GALConfig, GALResult
from repro.core.losses import Loss
from repro.core.organizations import make_orgs


def fit_joint(rng: jax.Array, xs: Sequence[jnp.ndarray], y: jnp.ndarray,
              loss: Loss, model, config: GALConfig = GALConfig(),
              eval_sets=None, metric_fn=None) -> GALResult:
    """Centralize all vertical slices into one org and run GAL (== GB)."""
    x_all = jnp.concatenate(list(xs), axis=-1) if isinstance(xs, (list, tuple)) \
        else xs
    orgs = make_orgs([x_all], model)
    eval_joined = None
    if eval_sets:
        eval_joined = {
            name: ([jnp.concatenate(list(xe), axis=-1)], ye)
            for name, (xe, ye) in eval_sets.items()
        }
    return gal.fit(rng, orgs, y, loss, config, eval_sets=eval_joined,
                   metric_fn=metric_fn)


def fit_alone(rng: jax.Array, x1: jnp.ndarray, y: jnp.ndarray, loss: Loss,
              model, config: GALConfig = GALConfig(), eval_sets=None,
              metric_fn=None) -> GALResult:
    """'Alone' bottom line: Alice boosts on her own slice only."""
    orgs = make_orgs([x1], model)
    return gal.fit(rng, orgs, y, loss, config, eval_sets=eval_sets,
                   metric_fn=metric_fn)
