"""Assisted Learning baseline (Xian et al., NeurIPS 2020) — paper Sec. 4.3.

AL trains participating organizations *sequentially* with a *constant*
assisted learning rate and no assistance weights: at each step one org fits
the current residual and is added to the ensemble. Communication rounds and
computation time are therefore M x those of GAL for the same number of
ensemble members (paper Table 14).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.losses import Loss
from repro.core.organizations import Organization


@dataclass
class ALResult:
    orgs: List[Organization]
    loss: Loss
    f0: jnp.ndarray
    order: List[int] = field(default_factory=list)   # org index per step
    eta: float = 1.0
    history: Dict[str, List[float]] = field(default_factory=dict)
    comm_rounds: int = 0

    def predict(self, xs: Sequence[jnp.ndarray]) -> jnp.ndarray:
        n = xs[0].shape[0]
        f = jnp.broadcast_to(self.f0, (n, self.f0.shape[-1]))
        fit_counts = {m: 0 for m in range(len(self.orgs))}
        for m in self.order:
            f = f + self.eta * self.orgs[m].predict_round(fit_counts[m], xs[m])
            fit_counts[m] += 1
        return f


def fit(rng: jax.Array, orgs: List[Organization], y: jnp.ndarray, loss: Loss,
        total_steps: int = 10, eta: float = 1.0,
        eval_sets: Optional[Dict[str, tuple]] = None,
        metric_fn=None) -> ALResult:
    """``total_steps`` sequential org fits, round-robin order."""
    for org in orgs:
        org.reset_round_state()  # a refit must not read stale round params
    n, k = y.shape[0], y.shape[-1]
    f0 = loss.init_prediction(y)
    f_train = jnp.broadcast_to(f0, (n, k))
    result = ALResult(orgs=orgs, loss=loss, f0=f0, eta=eta)
    hist = result.history
    hist["train_loss"] = [float(loss(y, f_train))]
    f_evals = {name: jnp.broadcast_to(f0, (ye.shape[0], k))
               for name, (_, ye) in (eval_sets or {}).items()}
    for name, (_, ye) in (eval_sets or {}).items():
        hist[f"{name}_loss"] = [float(loss(ye, f_evals[name]))]
        if metric_fn is not None:
            hist[f"{name}_metric"] = [float(metric_fn(ye, f_evals[name]))]

    fit_counts = {m: 0 for m in range(len(orgs))}
    for step in range(total_steps):
        m = step % len(orgs)
        residual = loss.residual(y, f_train)
        fitted = orgs[m].fit_round(jax.random.fold_in(rng, step), residual)
        f_train = f_train + eta * fitted
        result.order.append(m)
        result.comm_rounds += 1        # each sequential fit is a comm round
        hist["train_loss"].append(float(loss(y, f_train)))
        for name, (xs_e, ye) in (eval_sets or {}).items():
            f_evals[name] = f_evals[name] + eta * orgs[m].predict_round(
                fit_counts[m], xs_e[m]
            )
            hist[f"{name}_loss"].append(float(loss(ye, f_evals[name])))
            if metric_fn is not None:
                hist[f"{name}_metric"].append(
                    float(metric_fn(ye, f_evals[name]))
                )
        fit_counts[m] += 1
    return result
