"""Fused, scan-compiled GAL round engine (paper Algorithm 1, fast path).

The reference engine in ``repro.core.gal`` executes Algorithm 1 as a Python
loop: every round pays M Python dispatches for the local fits, a re-traced
line search, and several ``float()`` host round-trips for history keeping.
This module compiles the whole assistance stage into ONE device program for
the homogeneous-organization case (every org: same model class/config, same
local loss, tabular slices of a shared sample axis, no DMS, no output noise):

  * the per-org residual fits of round t are ``jax.vmap``-ed over org-stacked
    inputs ``(M, N, d_max)`` (vertical slices zero-padded to a common width —
    inert for the zoo models, see ``repro.data.partition.pad_and_stack``);
  * one round (residual -> privacy -> fits -> assistance weights -> eta
    line-search -> ensemble update -> eval bookkeeping) is a single traced
    step function;
  * the T-round loop is ``jax.lax.scan`` over that step, with etas, weights,
    per-round params and the loss/metric history materialized device-side.

The ONLY host synchronization is a single ``jax.device_get`` of the scalar
bundle after the scan returns — matching GAL's communication structure
(orgs are parallel within a round; rounds are sequential).

RNG discipline replicates the reference engine exactly (split per round;
``fold_in(k_round, 13)`` privacy, ``fold_in(k_round, org.index)`` per-org fit,
``fold_in(k_round, 29)`` weight fit), so for deterministic local models
(ridge / kernel ridge / stumps) the two engines agree to float tolerance.

Early stopping (``eta_stop_threshold``) cannot break a ``lax.scan``; instead
rounds after the threshold crossing are masked (eta forced to 0, ensemble
frozen) and trimmed from the returned history on the host side.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.losses import Loss, lq_loss
from repro.core.privacy import apply_privacy
from repro.core.weights import fit_weights, uniform_weights
from repro.data.partition import pad_and_stack
from repro.optim.lbfgs import line_search


def scan_compatible(orgs: Sequence[Any],
                    eval_sets: Optional[Dict[str, tuple]] = None) -> bool:
    """True when the fused vmap/scan fast path can run these organizations.

    Requirements: no Deep Model Sharing, no output noise (its prediction-stage
    noise keys are Python-``hash``-derived, untraceable), one shared scan-safe
    model config, one shared local ell_q, and org inputs that stack — rank-2
    slices over a common sample axis (padded) or identical higher-rank shapes.
    """
    if not orgs:
        return False
    first = orgs[0]
    for org in orgs:
        if not getattr(org, "scan_safe", False):
            return False
        if type(org.model) is not type(first.model) or org.model != first.model:
            return False
        if getattr(org.local_loss, "q", None) is None:
            return False
        if getattr(org.local_loss, "q") != getattr(first.local_loss, "q"):
            return False
    xs = [org.x_train for org in orgs]
    if not all(hasattr(x, "ndim") and hasattr(x, "shape") for x in xs):
        return False
    if any(x.ndim != xs[0].ndim or x.shape[0] != xs[0].shape[0] for x in xs):
        return False
    if xs[0].ndim != 2 and any(x.shape != xs[0].shape for x in xs):
        return False
    if xs[0].ndim == 2 and len({int(x.shape[-1]) for x in xs}) > 1:
        # unequal slices need zero-padding; randomly-initialized fits (MLP,
        # ConvNet, GRUNet, Linear q!=2) init params at the padded width, so
        # their draws — and hence auto-mode results — would silently differ
        # from the reference engine. Only pad-invariant fits stay eligible.
        inv = getattr(first.model, "pad_invariant", False)
        if callable(inv):
            inv = inv(getattr(first.local_loss, "q"))
        if not inv:
            return False
    if eval_sets:
        train_dims = [int(x.shape[-1]) for x in xs]
        for xs_e, _ in eval_sets.values():
            if len(xs_e) != len(orgs):
                return False
            if any(x.ndim != xs[0].ndim for x in xs_e):
                return False
            if any(x.shape[0] != xs_e[0].shape[0] for x in xs_e):
                return False
            if xs[0].ndim == 2:
                # org m's model is fit on train_dims[m] features; eval slices
                # must match per-org widths or the apply is semantically wrong
                if [int(x.shape[-1]) for x in xs_e] != train_dims:
                    return False
            elif any(x.shape[1:] != xs[0].shape[1:] for x in xs_e):
                return False
    return True


def metric_traceable(metric_fn: Callable,
                     eval_sets: Dict[str, tuple]) -> bool:
    """True when metric_fn traces cleanly over abstract (y_e, f) values.

    The fast path evaluates metric_fn under jit inside the scanned round
    step; ``engine="auto"`` probes it with ``jax.eval_shape`` first and
    falls back to the Python engine for host-side metrics (``float(...)``,
    numpy/sklearn calls) instead of crashing mid-trace.
    """
    try:
        for _, y_e in eval_sets.values():
            f_spec = jax.ShapeDtypeStruct((y_e.shape[0], y_e.shape[-1]),
                                          jnp.float32)
            y_spec = jax.ShapeDtypeStruct(y_e.shape, y_e.dtype)
            jax.eval_shape(metric_fn, y_spec, f_spec)
        return True
    except Exception:
        return False


def fit_scan(rng: jax.Array, orgs: Sequence[Any], y: jnp.ndarray, loss: Loss,
             config: Any, eval_sets: Optional[Dict[str, tuple]] = None,
             metric_fn: Optional[Callable] = None) -> Dict[str, Any]:
    """Run Algorithm 1 as one jitted scan; see the module docstring.

    Returns a dict with device-side stacked per-round ``params`` (leaves
    ``(T_valid, M, ...)``), host lists ``etas`` / ``weights``, the ``history``
    dict of Python floats, the padded input width ``pad_to`` and per-org
    slice widths ``dims`` (both needed to stack prediction-stage inputs).
    """
    m = len(orgs)
    model = orgs[0].model
    local_loss = orgs[0].local_loss
    n, k = y.shape[0], y.shape[-1]
    alice_loss = lq_loss(config.alice_q)
    masked = config.eta_stop_threshold > 0.0

    x_stack, dims = pad_and_stack([org.x_train for org in orgs])
    pad_to = int(x_stack.shape[-1]) if x_stack.ndim == 3 else None
    org_ids = jnp.asarray([org.index for org in orgs], jnp.uint32)
    eval_stacks = {}
    if eval_sets:
        for name, (xs_e, y_e) in eval_sets.items():
            xe_stack, _ = pad_and_stack(list(xs_e), pad_to=pad_to)
            eval_stacks[name] = (xe_stack, y_e)

    def run(key, y_in, x_in, evals_in):
        def round_step(carry, _):
            f, f_evals, key, active = carry
            key, k_round = jax.random.split(key)
            # 1. pseudo-residual  2. privatized broadcast
            residual = loss.residual(y_in, f)
            r_bcast = apply_privacy(
                jax.random.fold_in(k_round, 13), residual, config.privacy,
                alpha=config.privacy_alpha,
                n_intervals=config.privacy_intervals,
            )

            # 3. parallel local fits: one model vmapped over the org stack
            def fit_one(key_m, x_m):
                params = model.fit(key_m, x_m, r_bcast, local_loss)
                return params, model.apply(params, x_m)

            org_keys = jax.vmap(
                lambda i: jax.random.fold_in(k_round, i))(org_ids)
            params_t, preds = jax.vmap(fit_one)(org_keys, x_in)  # (M, N, K)

            # 4. gradient assistance weights
            if config.use_weights and m > 1:
                w = fit_weights(
                    jax.random.fold_in(k_round, 29), residual, preds,
                    alice_loss, epochs=config.weight_epochs,
                    lr=config.weight_lr, weight_decay=config.weight_decay,
                )
            else:
                w = uniform_weights(m)
            direction = jnp.einsum("m,mnk->nk", w, preds)

            # 5. line-search eta   6. masked ensemble update
            eta = line_search(
                lambda e: loss(y_in, f + e * direction),
                method=config.eta_method, x0=config.eta0,
            )
            eta_eff = jnp.where(active, eta, 0.0) if masked else eta
            f_new = f + eta_eff * direction

            outs = {"params": params_t, "eta": eta_eff, "w": w,
                    "valid": active, "train_loss": loss(y_in, f_new)}
            new_evals = {}
            for name, (xe_stack, y_e) in evals_in.items():
                preds_e = jax.vmap(model.apply)(params_t, xe_stack)
                fe = (f_evals[name]
                      + eta_eff * jnp.einsum("m,mnk->nk", w, preds_e))
                new_evals[name] = fe
                outs[f"{name}_loss"] = loss(y_e, fe)
                if metric_fn is not None:
                    outs[f"{name}_metric"] = metric_fn(y_e, fe)
            new_active = (active & (jnp.abs(eta) >= config.eta_stop_threshold)
                          if masked else active)
            return (f_new, new_evals, key, new_active), outs

        f = jnp.broadcast_to(loss.init_prediction(y_in), (n, k))
        f_evals = {
            name: jnp.broadcast_to(loss.init_prediction(y_in), (y_e.shape[0], k))
            for name, (_, y_e) in evals_in.items()
        }
        init = {"train_loss": loss(y_in, f)}
        for name, (_, y_e) in evals_in.items():
            init[f"{name}_loss"] = loss(y_e, f_evals[name])
            if metric_fn is not None:
                init[f"{name}_metric"] = metric_fn(y_e, f_evals[name])
        carry0 = (f, f_evals, key, jnp.asarray(True))
        _, outs = jax.lax.scan(round_step, carry0, None, length=config.rounds)
        return outs, init

    outs, init = jax.jit(run)(rng, y, x_stack, eval_stacks)
    params_stacked = outs.pop("params")           # stays on device
    scalars, init = jax.device_get((outs, init))  # the ONE host sync

    n_valid = int(scalars["valid"].sum()) if masked else config.rounds
    history = {"train_loss": [float(init["train_loss"])]
               + [float(v) for v in scalars["train_loss"][:n_valid]]}
    for name in eval_stacks:
        for kind in ("loss", "metric"):
            col = f"{name}_{kind}"
            if col in scalars:
                history[col] = [float(init[col])] + [
                    float(v) for v in scalars[col][:n_valid]]
    return {
        "params": jax.tree_util.tree_map(lambda l: l[:n_valid], params_stacked),
        "etas": [float(e) for e in scalars["eta"][:n_valid]],
        "weights": [jnp.asarray(w) for w in scalars["w"][:n_valid]],
        "history": history,
        "dims": dims,
        "pad_to": pad_to,
    }


def stacked_predict(model: Any, stacked_params: Any, etas: Sequence[float],
                    weights: Sequence[jnp.ndarray], f0: jnp.ndarray,
                    xs: Sequence[jnp.ndarray], pad_to: Optional[int],
                    t_max: int,
                    org_dims: Optional[Sequence[int]] = None) -> jnp.ndarray:
    """Prediction stage as ONE vmap over (rounds x orgs).

    F^T(x*) = F^0 + sum_t eta^t sum_m w^t_m f^t_m(x*_m), with the (T, M)
    ensemble applied by a nested vmap and contracted in a single einsum —
    no per-(round, org) Python dispatch.
    """
    if org_dims is not None and xs[0].ndim == 2:
        # the zero-pad would silently swallow mis-sized/mis-ordered slices
        # that the reference engine rejects with a shape error — keep that net
        got = [int(x.shape[-1]) for x in xs]
        if got != list(org_dims):
            raise ValueError(
                f"prediction slice widths {got} do not match the fitted "
                f"per-org widths {list(org_dims)} (check org order)")
    n = xs[0].shape[0]
    f = jnp.broadcast_to(f0, (n, f0.shape[-1]))
    if t_max == 0:
        return f
    x_stack, _ = pad_and_stack(list(xs), pad_to=pad_to)
    params_t = jax.tree_util.tree_map(lambda l: l[:t_max], stacked_params)
    preds = jax.vmap(lambda p: jax.vmap(model.apply)(p, x_stack))(params_t)
    etas_t = jnp.asarray(etas[:t_max], jnp.float32)
    w_t = jnp.stack(list(weights[:t_max]))
    return f + jnp.einsum("t,tm,tmnk->nk", etas_t, w_t, preds)
