"""Fused, scan-compiled GAL round engines (paper Algorithm 1, fast paths).

The reference engine in ``repro.core.gal`` executes Algorithm 1 as a Python
loop: every round pays M Python dispatches for the local fits, a re-traced
line search, and several ``float()`` host round-trips for history keeping.
This module compiles the whole assistance stage into ONE device program for
the homogeneous-organization case (every org: same model class/config, same
local loss, tabular slices of a shared sample axis, no DMS, no output noise):

  * the per-org residual fits of round t are ``jax.vmap``-ed over org-stacked
    inputs ``(M, N, d_max)`` (vertical slices zero-padded to a common width —
    inert for the zoo models, see ``repro.data.partition.pad_and_stack``);
  * one round (residual -> privacy -> fits -> assistance weights -> eta
    line-search -> ensemble update -> eval bookkeeping) is a single traced
    step function;
  * the T-round loop is ``jax.lax.scan`` over that step, with etas, weights,
    per-round params and the loss/metric history materialized device-side.

The ONLY host synchronization is a single ``jax.device_get`` of the scalar
bundle after the scan returns — matching GAL's communication structure
(orgs are parallel within a round; rounds are sequential).

Two fused executions share that round step structure:

  * ``fit_scan`` — the single-device fast path: the org axis is a
    ``jax.vmap`` over the stacked slices;
  * ``fit_shard`` — the org-SHARDED multi-device path
    (``GALConfig.engine="shard"``): the org axis maps onto a real device
    mesh (``repro.launch.mesh.make_org_mesh``, one organization per device
    along an "org" axis). Each org's padded slice, per-round params and
    local fits live on its own device; Alg. 1's communication structure
    becomes real collectives — the residual broadcast is a masked ``psum``
    from Alice's device (step 2), the fitted values are ``all_gather``-ed
    back for the weight fit (step 4), and the weighted direction is a
    ``psum`` over the org axis (step 6). The bytes crossing that collective
    boundary are recorded in a per-round communication ledger
    (``history["comm_broadcast_bytes"]`` / ``history["comm_gather_bytes"]``,
    mirroring the paper's Table-14 accounting in
    ``repro.core.protocol_sim``).

RNG discipline replicates the reference engine exactly (split per round;
``fold_in(k_round, 13)`` privacy, ``fold_in(k_round, org.index)`` per-org fit,
``fold_in(k_round, 29)`` weight fit), so for deterministic local models
(ridge / kernel ridge / stumps) all three engines agree to float tolerance.

Early stopping (``eta_stop_threshold``) cannot break a ``lax.scan``; instead
rounds after the threshold crossing are masked (eta forced to 0, ensemble
frozen) and trimmed from the returned history on the host side.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.losses import Loss, lq_loss
from repro.core.privacy import apply_privacy
from repro.core.weights import fit_weights, uniform_weights
from repro.data.partition import pad_and_stack, pad_and_stack_sharded
from repro.launch.mesh import make_org_mesh, org_mesh_eligible
from repro.launch.sharding import org_replicated, org_stack_sharding
from repro.optim.lbfgs import line_search

_WIRE_ITEMSIZE = 4  # residuals / fitted values travel as f32 on the wire


def scan_compatible(orgs: Sequence[Any],
                    eval_sets: Optional[Dict[str, tuple]] = None) -> bool:
    """True when the fused vmap/scan fast path can run these organizations.

    Requirements: no Deep Model Sharing, no output noise (its prediction-stage
    noise keys are Python-``hash``-derived, untraceable), one shared scan-safe
    model config, one shared local ell_q, and org inputs that stack — rank-2
    slices over a common sample axis (padded) or identical higher-rank shapes.
    """
    if not orgs:
        return False
    first = orgs[0]
    for org in orgs:
        if not getattr(org, "scan_safe", False):
            return False
        if type(org.model) is not type(first.model) or org.model != first.model:
            return False
        if getattr(org.local_loss, "q", None) is None:
            return False
        if getattr(org.local_loss, "q") != getattr(first.local_loss, "q"):
            return False
    xs = [org.x_train for org in orgs]
    if not all(hasattr(x, "ndim") and hasattr(x, "shape") for x in xs):
        return False
    if any(x.ndim != xs[0].ndim or x.shape[0] != xs[0].shape[0] for x in xs):
        return False
    if xs[0].ndim != 2 and any(x.shape != xs[0].shape for x in xs):
        return False
    if xs[0].ndim == 2 and len({int(x.shape[-1]) for x in xs}) > 1:
        # unequal slices need zero-padding; randomly-initialized fits (MLP,
        # ConvNet, GRUNet, Linear q!=2) init params at the padded width, so
        # their draws — and hence auto-mode results — would silently differ
        # from the reference engine. Only pad-invariant fits stay eligible.
        inv = getattr(first.model, "pad_invariant", False)
        if callable(inv):
            inv = inv(getattr(first.local_loss, "q"))
        if not inv:
            return False
    if eval_sets:
        train_dims = [int(x.shape[-1]) for x in xs]
        for xs_e, _ in eval_sets.values():
            if len(xs_e) != len(orgs):
                return False
            if any(x.ndim != xs[0].ndim for x in xs_e):
                return False
            if any(x.shape[0] != xs_e[0].shape[0] for x in xs_e):
                return False
            if xs[0].ndim == 2:
                # org m's model is fit on train_dims[m] features; eval slices
                # must match per-org widths or the apply is semantically wrong
                if [int(x.shape[-1]) for x in xs_e] != train_dims:
                    return False
            elif any(x.shape[1:] != xs[0].shape[1:] for x in xs_e):
                return False
    return True


def metric_traceable(metric_fn: Callable,
                     eval_sets: Dict[str, tuple]) -> bool:
    """True when metric_fn traces cleanly over abstract (y_e, f) values.

    The fast path evaluates metric_fn under jit inside the scanned round
    step; ``engine="auto"`` probes it with ``jax.eval_shape`` first and
    falls back to the Python engine for host-side metrics (``float(...)``,
    numpy/sklearn calls) instead of crashing mid-trace.
    """
    try:
        for _, y_e in eval_sets.values():
            f_spec = jax.ShapeDtypeStruct((y_e.shape[0], y_e.shape[-1]),
                                          jnp.float32)
            y_spec = jax.ShapeDtypeStruct(y_e.shape, y_e.dtype)
            jax.eval_shape(metric_fn, y_spec, f_spec)
        return True
    except Exception:
        return False


def shard_eligible(orgs: Sequence[Any],
                   eval_sets: Optional[Dict[str, tuple]] = None) -> bool:
    """True when the org-sharded multi-device path can run these orgs:
    scan-compatible AND an "org" mesh exists (len(orgs) divides the local
    device count, multi-device host). ``engine="auto"`` prefers this path
    whenever it holds."""
    return (scan_compatible(orgs, eval_sets)
            and org_mesh_eligible(len(orgs)))


def _finalize(outs: Dict[str, Any], init: Dict[str, Any], masked: bool,
              rounds: int, dims: Sequence[int], pad_to: Optional[int],
              comm: Optional[Dict[str, int]] = None) -> Dict[str, Any]:
    """Shared host-side tail of the fused engines: ONE ``jax.device_get``
    of the scalar bundle, early-stop trimming, history assembly.

    History columns: train/eval losses and metrics get the round-0 ``init``
    entry prepended (length T+1); ``comm`` maps ledger columns to exact
    per-round byte counts (static shapes -> identical every round), added
    as length-T rows of Python ints so the accounting never loses precision
    to f32 at scale."""
    params_stacked = outs.pop("params")           # stays on device
    scalars, init = jax.device_get((outs, init))  # the ONE host sync
    n_valid = int(scalars["valid"].sum()) if masked else rounds
    history: Dict[str, List[float]] = {}
    for col, vals in scalars.items():
        if col in ("eta", "w", "valid"):
            continue
        history[col] = [float(init[col])] + [float(v) for v in vals[:n_valid]]
    for col, per_round in (comm or {}).items():
        history[col] = [per_round] * n_valid
    return {
        "params": jax.tree_util.tree_map(lambda l: l[:n_valid], params_stacked),
        "etas": [float(e) for e in scalars["eta"][:n_valid]],
        "weights": [jnp.asarray(w) for w in scalars["w"][:n_valid]],
        "history": history,
        "dims": dims,
        "pad_to": pad_to,
    }


def _run_rounds(key, y_in, evals_in, broadcast, fit_orgs, *, loss, config,
                m, n, k, masked, metric_fn, alice_loss):
    """The shared T-round loop of both fused engines: Alg. 1 steps 1-6
    traced once and scanned ``config.rounds`` times.

    The org axis enters ONLY through two primitives supplied by the caller:

      * ``broadcast(r)`` — step 2's residual distribution (identity on the
        vmap engine; a masked psum from Alice's device on the mesh engine);
      * ``fit_orgs(k_round, r_bcast) -> (params_out, preds, combine)`` —
        step 3's parallel fits. ``params_out`` is the per-round params
        output (M-stacked / org-sharded), ``preds`` the (M, N, K) fitted
        values handed to the step-4 weight fit, and ``combine(w, name)``
        the weighted org-sum of fitted values on the train set
        (``name=None``) or eval set ``name`` (einsum vs psum).

    Everything else — residual, privacy, weight fit, eta line search,
    masked early stopping, history bookkeeping — is engine-independent and
    lives here exactly once.
    """
    def round_step(carry, _):
        f, f_evals, key, active = carry
        key, k_round = jax.random.split(key)
        # 1. pseudo-residual  2. privatized broadcast
        residual = loss.residual(y_in, f)
        r_bcast = broadcast(apply_privacy(
            jax.random.fold_in(k_round, 13), residual, config.privacy,
            alpha=config.privacy_alpha,
            n_intervals=config.privacy_intervals,
        ))
        # 3. parallel local fits over the org axis
        params_out, preds, combine = fit_orgs(k_round, r_bcast)
        # 4. gradient assistance weights
        if config.use_weights and m > 1:
            w = fit_weights(
                jax.random.fold_in(k_round, 29), residual, preds,
                alice_loss, epochs=config.weight_epochs,
                lr=config.weight_lr, weight_decay=config.weight_decay,
            )
        else:
            w = uniform_weights(m)
        direction = combine(w, None)

        # 5. line-search eta   6. masked ensemble update
        eta = line_search(
            lambda e: loss(y_in, f + e * direction),
            method=config.eta_method, x0=config.eta0,
        )
        eta_eff = jnp.where(active, eta, 0.0) if masked else eta
        f_new = f + eta_eff * direction

        outs = {"params": params_out, "eta": eta_eff, "w": w,
                "valid": active, "train_loss": loss(y_in, f_new)}
        new_evals = {}
        for name, (_, y_e) in evals_in.items():
            fe = f_evals[name] + eta_eff * combine(w, name)
            new_evals[name] = fe
            outs[f"{name}_loss"] = loss(y_e, fe)
            if metric_fn is not None:
                outs[f"{name}_metric"] = metric_fn(y_e, fe)
        new_active = (active & (jnp.abs(eta) >= config.eta_stop_threshold)
                      if masked else active)
        return (f_new, new_evals, key, new_active), outs

    f = jnp.broadcast_to(loss.init_prediction(y_in), (n, k))
    f_evals = {
        name: jnp.broadcast_to(loss.init_prediction(y_in), (y_e.shape[0], k))
        for name, (_, y_e) in evals_in.items()
    }
    init = {"train_loss": loss(y_in, f)}
    for name, (_, y_e) in evals_in.items():
        init[f"{name}_loss"] = loss(y_e, f_evals[name])
        if metric_fn is not None:
            init[f"{name}_metric"] = metric_fn(y_e, f_evals[name])
    carry0 = (f, f_evals, key, jnp.asarray(True))
    _, outs = jax.lax.scan(round_step, carry0, None, length=config.rounds)
    return outs, init


def fit_scan(rng: jax.Array, orgs: Sequence[Any], y: jnp.ndarray, loss: Loss,
             config: Any, eval_sets: Optional[Dict[str, tuple]] = None,
             metric_fn: Optional[Callable] = None) -> Dict[str, Any]:
    """Run Algorithm 1 as one jitted scan; see the module docstring.

    Returns a dict with device-side stacked per-round ``params`` (leaves
    ``(T_valid, M, ...)``), host lists ``etas`` / ``weights``, the ``history``
    dict of Python floats, the padded input width ``pad_to`` and per-org
    slice widths ``dims`` (both needed to stack prediction-stage inputs).
    """
    m = len(orgs)
    model = orgs[0].model
    local_loss = orgs[0].local_loss
    n, k = y.shape[0], y.shape[-1]
    alice_loss = lq_loss(config.alice_q)
    masked = config.eta_stop_threshold > 0.0

    x_stack, dims = pad_and_stack([org.x_train for org in orgs])
    pad_to = int(x_stack.shape[-1]) if x_stack.ndim == 3 else None
    org_ids = jnp.asarray([org.index for org in orgs], jnp.uint32)
    eval_stacks = {}
    if eval_sets:
        for name, (xs_e, y_e) in eval_sets.items():
            xe_stack, _ = pad_and_stack(list(xs_e), pad_to=pad_to)
            eval_stacks[name] = (xe_stack, y_e)

    def run(key, y_in, x_in, evals_in):
        def fit_orgs(k_round, r_bcast):
            # one model vmapped over the org stack
            def fit_one(key_m, x_m):
                params = model.fit(key_m, x_m, r_bcast, local_loss)
                return params, model.apply(params, x_m)

            org_keys = jax.vmap(
                lambda i: jax.random.fold_in(k_round, i))(org_ids)
            params_t, preds = jax.vmap(fit_one)(org_keys, x_in)  # (M, N, K)

            def combine(w, name):
                if name is None:
                    return jnp.einsum("m,mnk->nk", w, preds)
                preds_e = jax.vmap(model.apply)(params_t, evals_in[name][0])
                return jnp.einsum("m,mnk->nk", w, preds_e)

            return params_t, preds, combine

        return _run_rounds(key, y_in, evals_in, lambda r: r, fit_orgs,
                           loss=loss, config=config, m=m, n=n, k=k,
                           masked=masked, metric_fn=metric_fn,
                           alice_loss=alice_loss)

    outs, init = jax.jit(run)(rng, y, x_stack, eval_stacks)
    return _finalize(outs, init, masked, config.rounds, dims, pad_to)


def fit_shard(rng: jax.Array, orgs: Sequence[Any], y: jnp.ndarray, loss: Loss,
              config: Any, eval_sets: Optional[Dict[str, tuple]] = None,
              metric_fn: Optional[Callable] = None) -> Dict[str, Any]:
    """Run Algorithm 1 org-sharded across devices (see the module docstring).

    Same contract as ``fit_scan`` — the T-round ``lax.scan``, the single
    host sync, and the returned dict are identical — but the org axis is a
    real device mesh instead of a ``vmap``: org m's padded slice, per-round
    params, and fitted values never leave device m except through Alg. 1's
    three collectives (residual broadcast, fitted-value gather, weighted
    direction psum). The returned history carries the per-round
    communication ledger (``comm_broadcast_bytes`` / ``comm_gather_bytes``,
    paper Table-14 convention: Alice already holds her own residual copy,
    every org — Alice included — ships its fitted values)."""
    m = len(orgs)
    if not org_mesh_eligible(m):
        raise ValueError(
            f"engine='shard' needs an org mesh: {m} orgs must divide the "
            f"device count ({jax.device_count()} devices, multi-device "
            f"host required)")
    mesh = make_org_mesh(m)
    model = orgs[0].model
    local_loss = orgs[0].local_loss
    n, k = y.shape[0], y.shape[-1]
    alice_loss = lq_loss(config.alice_q)
    masked = config.eta_stop_threshold > 0.0

    # org-major placement: slice m / id m on device m, Alice state replicated
    x_stack, dims = pad_and_stack_sharded([org.x_train for org in orgs], mesh)
    pad_to = int(x_stack.shape[-1]) if x_stack.ndim == 3 else None
    org_ids = jax.device_put(
        jnp.asarray([org.index for org in orgs], jnp.uint32),
        org_stack_sharding(mesh, 1))
    y_dev = jax.device_put(y, org_replicated(mesh))
    eval_stacks, eval_in_specs = {}, {}
    if eval_sets:
        for name, (xs_e, y_e) in eval_sets.items():
            xe_stack, _ = pad_and_stack_sharded(list(xs_e), mesh,
                                                pad_to=pad_to)
            eval_stacks[name] = (xe_stack,
                                 jax.device_put(y_e, org_replicated(mesh)))
            eval_in_specs[name] = (P("org"), P())

    def run(key, y_in, x_in, ids_in, evals_in):
        my_x = x_in[0]                 # this device's org slice (N, d_max)
        my_id = ids_in[0]
        pos = jax.lax.axis_index("org")

        def broadcast(r_wire):
            # step 2 as a REAL collective: only Alice's device (org position
            # 0) contributes, so the psum equals her privatized residual
            # exactly while crossing every device boundary
            return jax.lax.psum(
                jnp.where(pos == 0, r_wire, jnp.zeros_like(r_wire)), "org")

        def fit_orgs(k_round, r_bcast):
            # THIS device's local fit only (the scan engine's vmap axis
            # became the mesh axis); RNG key identical to the other engines
            params_m = model.fit(jax.random.fold_in(k_round, my_id), my_x,
                                 r_bcast, local_loss)
            pred_m = model.apply(params_m, my_x)          # (N, K)
            # step 4's inputs: fitted values gathered back to Alice
            preds = jax.lax.all_gather(pred_m, "org")     # (M, N, K)

            def combine(w, name):
                # weighted org-sum as a psum over the mesh axis
                out_m = pred_m if name is None \
                    else model.apply(params_m, evals_in[name][0][0])
                return jax.lax.psum(w[pos] * out_m, "org")

            params_out = jax.tree_util.tree_map(lambda l: l[None], params_m)
            return params_out, preds, combine

        return _run_rounds(key, y_in, evals_in, broadcast, fit_orgs,
                           loss=loss, config=config, m=m, n=n, k=k,
                           masked=masked, metric_fn=metric_fn,
                           alice_loss=alice_loss)

    # everything in the scalar bundle is replicated (collectives + identical
    # per-device programs on replicated inputs); only the per-round params
    # keep an org axis, split over the mesh
    out_specs = {"params": P(None, "org"), "eta": P(), "w": P(),
                 "valid": P(), "train_loss": P()}
    for name in eval_stacks:
        out_specs[f"{name}_loss"] = P()
        if metric_fn is not None:
            out_specs[f"{name}_metric"] = P()
    run_sharded = shard_map(
        run, mesh=mesh,
        in_specs=(P(), P(), P("org"), P("org"), eval_in_specs),
        out_specs=(out_specs, P()),
        check_rep=False,
    )
    outs, init = jax.jit(run_sharded)(rng, y_dev, x_stack, org_ids,
                                      eval_stacks)
    # per-round ledger of the three collectives above, from the (static)
    # operand shapes — exact ints, Table-14 convention: Alice already holds
    # her residual copy (M-1 broadcast legs); all M orgs ship fitted values
    # for the train AND eval prediction stages
    resid_bytes = n * k * _WIRE_ITEMSIZE
    comm = {
        "comm_broadcast_bytes": (m - 1) * resid_bytes,
        "comm_gather_bytes": m * resid_bytes + sum(
            m * int(y_e.shape[0]) * k * _WIRE_ITEMSIZE
            for (_, y_e) in eval_stacks.values()),
    }
    return _finalize(outs, init, masked, config.rounds, dims, pad_to,
                     comm=comm)


def stacked_predict(model: Any, stacked_params: Any, etas: Sequence[float],
                    weights: Sequence[jnp.ndarray], f0: jnp.ndarray,
                    xs: Sequence[jnp.ndarray], pad_to: Optional[int],
                    t_max: int,
                    org_dims: Optional[Sequence[int]] = None) -> jnp.ndarray:
    """Prediction stage as ONE vmap over (rounds x orgs).

    F^T(x*) = F^0 + sum_t eta^t sum_m w^t_m f^t_m(x*_m), with the (T, M)
    ensemble applied by a nested vmap and contracted in a single einsum —
    no per-(round, org) Python dispatch.
    """
    if org_dims is not None and xs[0].ndim == 2:
        # the zero-pad would silently swallow mis-sized/mis-ordered slices
        # that the reference engine rejects with a shape error — keep that net
        got = [int(x.shape[-1]) for x in xs]
        if got != list(org_dims):
            raise ValueError(
                f"prediction slice widths {got} do not match the fitted "
                f"per-org widths {list(org_dims)} (check org order)")
    n = xs[0].shape[0]
    f = jnp.broadcast_to(f0, (n, f0.shape[-1]))
    if t_max == 0:
        return f
    x_stack, _ = pad_and_stack(list(xs), pad_to=pad_to)
    params_t = jax.tree_util.tree_map(lambda l: l[:t_max], stacked_params)
    preds = jax.vmap(lambda p: jax.vmap(model.apply)(p, x_stack))(params_t)
    etas_t = jnp.asarray(etas[:t_max], jnp.float32)
    w_t = jnp.stack(list(weights[:t_max]))
    return f + jnp.einsum("t,tm,tmnk->nk", etas_t, w_t, preds)
