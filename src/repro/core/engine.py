"""Fused, scan-compiled GAL round engines (paper Algorithm 1, fast paths).

The reference engine in ``repro.core.gal`` executes Algorithm 1 as a Python
loop: every round pays M Python dispatches for the local fits, a re-traced
line search, and several ``float()`` host round-trips for history keeping.
This module compiles the whole assistance stage into ONE device program for
every organization set the execution planner (``repro.core.plan``) can
partition into homogeneous groups — including the paper's heterogeneous
scenarios (model autonomy's GB–SVM mix, per-org local ell_q losses, noisy
orgs). Per traced round:

  * each planner group's residual fits are ``jax.vmap``-ed over that group's
    stacked inputs ``(M_g, N, d_g)`` (vertical slices zero-padded to a
    common width *within the group* — inert for pad-invariant fits,
    width-split groups otherwise; see ``repro.data.partition.stack_groups``);
  * the group fitted values are concatenated along the org axis — back in
    original org order — before the step-4 weight fit, so Algorithm 1 sees
    one (M, N, K) block exactly as the reference engine does;
  * one round (residual -> privacy -> group fits -> assistance weights ->
    eta line-search -> ensemble update -> eval bookkeeping) is a single
    traced step function;
  * the T-round loop is ``jax.lax.scan`` over that step, with etas, weights,
    per-round params and the loss/metric history materialized device-side.

The ONLY host synchronization is a single ``jax.device_get`` of the scalar
bundle after the scan returns — matching GAL's communication structure
(orgs are parallel within a round; rounds are sequential).

Noisy organizations (paper Table 6) are traceable end to end: training-stage
noise uses the same ``fold_in(org_key, 777)`` keys as the reference engine,
and prediction-stage noise derives from ``fold_in(PRNGKey(org.index), t)``
(see ``Organization.predict_round``) — no Python ``hash`` anywhere — so the
grouped engine, the Python loop, and the stacked prediction path all draw
identical noise for a given (org, round).

Deep Model Sharing (paper Sec. 4.2/5) is traceable too: a DMS group's
shared extractor and its per-round heads ride the round scan's carry with
FIXED shapes — the heads as one stacked ``(M_g, T, ...)`` buffer, the
broadcast-residual history as a shared ``(T, N, K)`` buffer — and each
round's joint refit (``_dms_org_round``) masks the not-yet-live head slots
out of the objective, so their gradients are exactly zero and the refit
reproduces ``Organization._fit_round_dms`` term for term. The Table-14
memory win is ledgered per round in ``history["model_memories"]``.

The fused executions share that round step structure:

  * ``fit_grouped`` — the planner-driven engine: one vmap per group inside
    the shared round step; on a multi-device host where the device count
    divides every group size, each group's org stack is placed sharded
    along an "org" mesh axis (``launch.mesh.grouped_mesh_eligible``), so a
    mixed-model org set maps onto the mesh with one org-shard of every
    group per device;
  * ``fit_scan`` — the legacy single-group veneer over ``fit_grouped``
    (homogeneous orgs, single host);
  * ``fit_shard`` — the org-SHARDED multi-device path
    (``GALConfig.engine="shard"``): the org axis maps onto a real device
    mesh (``repro.launch.mesh.make_org_mesh``, one organization per device
    along an "org" axis). Each org's padded slice, per-round params and
    local fits live on its own device; Alg. 1's communication structure
    becomes real collectives — the residual broadcast is a masked ``psum``
    from Alice's device (step 2), the fitted values are ``all_gather``-ed
    back for the weight fit (step 4), and the weighted direction is a
    ``psum`` over the org axis (step 6). The bytes crossing that collective
    boundary are recorded in a per-round communication ledger
    (``history["comm_broadcast_bytes"]`` / ``history["comm_gather_bytes"]``,
    mirroring the paper's Table-14 accounting in
    ``repro.core.protocol_sim``).

RNG discipline replicates the reference engine exactly (split per round;
``fold_in(k_round, 13)`` privacy, ``fold_in(k_round, org.index)`` per-org fit,
``fold_in(k_round, 29)`` weight fit), so for deterministic local models
(ridge / kernel ridge / stumps) all three engines agree to float tolerance.

Early stopping (``eta_stop_threshold``) cannot break a ``lax.scan``; instead
rounds after the threshold crossing are masked (eta forced to 0, ensemble
frozen) and trimmed from the returned history on the host side.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.losses import Loss, lq_loss
from repro.core.plan import ExecutionPlan, plan_orgs
from repro.core.privacy import apply_privacy
from repro.core.protocol_sim import gal_model_memories, gal_round_bytes
from repro.core.weights import fit_weights, uniform_weights
from repro.optim.optimizers import adam, apply_updates
from repro.data.partition import (pad_and_stack, pad_and_stack_sharded,
                                  stack_groups)
from repro.launch.mesh import (grouped_mesh_eligible, make_org_mesh,
                               org_block_size, org_mesh_eligible)
from repro.launch.sharding import org_replicated, org_stack_sharding
from repro.optim.lbfgs import line_search


def scan_compatible(orgs: Sequence[Any],
                    eval_sets: Optional[Dict[str, tuple]] = None) -> bool:
    """True when the legacy single-group fast path can run these orgs: the
    planner compiles them into exactly ONE noiseless group (one shared
    scan-safe model config, one shared ell_q, stackable slices, no DMS).
    Heterogeneous / noisy / per-loss sets that still compile — as multiple
    groups — are the grouped engine's territory (``plan_orgs(...).compiled``)
    and return False here."""
    p = plan_orgs(orgs, eval_sets)
    return p.compiled and p.homogeneous


def metric_traceable(metric_fn: Callable,
                     eval_sets: Dict[str, tuple]) -> bool:
    """True when metric_fn traces cleanly over abstract (y_e, f) values.

    EVERY engine evaluates metrics under jit inside the round loop now
    (the host-side escape hatch is retired); ``gal.fit`` probes each
    metric with ``jax.eval_shape`` up front and raises — naming the
    ``repro.metrics.METRICS`` registry — for host-side callables
    (``float(...)``, numpy/sklearn calls) instead of crashing mid-trace.
    """
    try:
        for _, y_e in eval_sets.values():
            f_spec = jax.ShapeDtypeStruct((y_e.shape[0], y_e.shape[-1]),
                                          jnp.float32)
            y_spec = jax.ShapeDtypeStruct(y_e.shape, y_e.dtype)
            jax.eval_shape(metric_fn, y_spec, f_spec)
        return True
    except Exception:
        return False


def shard_eligible(orgs: Sequence[Any],
                   eval_sets: Optional[Dict[str, tuple]] = None,
                   data_shards: int = 1) -> bool:
    """True when the org-sharded multi-device path can run these orgs:
    scan-compatible AND an "org" mesh exists — one-to-one (len(orgs)
    divides the org-axis device count) or block placement (the org-axis
    device count divides len(orgs), a block of orgs per device); see
    ``launch.mesh.org_mesh_eligible``. ``engine="auto"`` prefers this path
    whenever it holds."""
    return (scan_compatible(orgs, eval_sets)
            and org_mesh_eligible(len(orgs), data_shards))


def _finalize(outs: Dict[str, Any], init: Dict[str, Any], masked: bool,
              rounds: int, dims: Sequence[int], pad_to: Optional[int],
              comm: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Shared host-side tail of the fused engines: ONE ``jax.device_get``
    of the scalar bundle, early-stop trimming, history assembly.

    History columns: train/eval losses and metrics get the round-0 ``init``
    entry prepended (length T+1); ``comm`` maps ledger columns to exact
    per-round Python ints (so the accounting never loses precision to f32
    at scale) — either one value repeated every round (static collective
    shapes) or a length-``rounds`` list (e.g. the model-memory ledger,
    which grows per round for fresh-fit orgs), trimmed like every other
    column on early stop."""
    params_stacked = outs.pop("params")           # stays on device
    scalars, init = jax.device_get((outs, init))  # the ONE host sync
    n_valid = int(scalars["valid"].sum()) if masked else rounds
    history: Dict[str, List[float]] = {}
    for col, vals in scalars.items():
        if col in ("eta", "w", "valid"):
            continue
        history[col] = [float(init[col])] + [float(v) for v in vals[:n_valid]]
    for col, per_round in (comm or {}).items():
        history[col] = (list(per_round[:n_valid])
                        if isinstance(per_round, (list, tuple))
                        else [per_round] * n_valid)
    return {
        "params": jax.tree_util.tree_map(lambda l: l[:n_valid], params_stacked),
        "etas": [float(e) for e in scalars["eta"][:n_valid]],
        "weights": [jnp.asarray(w) for w in scalars["w"][:n_valid]],
        "history": history,
        "dims": dims,
        "pad_to": pad_to,
    }


def _resid_wire_bytes(config) -> int:
    """Per-element width of the residual broadcast on the wire (step 2):
    2 under ``GALConfig(residual_dtype="bf16")``, 4 otherwise. The ONE
    place the ledgers and the engines read the compressed-broadcast knob."""
    return 2 if getattr(config, "residual_dtype", "float32") in (
        "bf16", "bfloat16") else 4


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def _grad_allreduce(x, axes):
    """Identity whose VJP psums the cotangent over ``axes``.

    Inside ``shard_map`` a ``psum`` in the loss transposes to identity, so
    ``jax.grad`` of a psum'd global-mean objective yields only the LOCAL
    shard's gradient contribution — correct values, shard-local gradients.
    Wrapping a replicated scalar input (the line-search eta) in this
    primitive reassembles the global gradient at the leaf, the same
    correction ``fit_weights(grad_axes=...)`` applies explicitly per step."""
    return x


def _grad_allreduce_fwd(x, axes):
    return x, None


def _grad_allreduce_bwd(axes, _, ct):
    for ax in axes:
        ct = jax.lax.psum(ct, ax)
    return (ct,)


_grad_allreduce.defvjp(_grad_allreduce_fwd, _grad_allreduce_bwd)


def _run_rounds(key, y_in, evals_in, broadcast, fit_orgs, *, loss, config,
                m, n, k, masked, metrics, alice_loss, state0=(), t0=0,
                restore=None, member_sched=None, org_ids=None,
                wfit_kwargs=None, f0=None, eta_grad_axes=()):
    """The shared T-round loop of both fused engines: Alg. 1 steps 1-6
    traced once and scanned over rounds ``t0 .. config.rounds`` (``t0=0``
    for a fresh fit; a resumed fit restores the scan carry and picks up
    mid-sequence).

    The org axis enters ONLY through two primitives supplied by the caller:

      * ``broadcast(r)`` — step 2's residual distribution (identity on the
        vmap engine; a masked psum from Alice's device on the mesh engine);
      * ``fit_orgs(k_round, r_bcast, t, state, active)
        -> (state, params_out, preds, combine)`` — step 3's parallel fits.
        ``state`` is the caller's opaque carry through the round scan (the
        DMS groups' shared extractor / stacked-head buffers; ``()`` for
        stateless engines) — updates must be frozen when ``active`` is
        False so early-stopped rounds leave it untouched. ``params_out``
        is the per-round params output (group-stacked / org-sharded; an
        EMPTY pytree for state-carried groups), ``preds`` the (M, N, K)
        fitted values — in org order — handed to the step-4 weight fit, and
        ``combine(w, name)`` the weighted org-sum of fitted values on the
        train set (``name=None``) or eval set ``name`` (einsum vs psum).
        ``t`` is the 0-based round index, which noisy groups fold into the
        prediction-stage noise keys.

    ``metrics`` maps metric names to in-trace callables ``(y, f) ->
    scalar`` (the device-side metric registry, ``repro.metrics.METRICS``);
    each eval set gets one history column per metric, so the whole eval
    curve stays inside the single post-scan host sync.

    ``restore`` resumes an interrupted collaboration: a
    ``(f, f_evals, active)`` triple (the artifact's saved carry — the
    ensemble state after round ``t0``, the per-eval-set carries, and the
    early-stop flag) replaces the cold-start carry, and ``key`` must be
    the post-round-``t0`` RNG key, so the scanned rounds ``t0..T`` draw
    exactly what an uninterrupted ``T``-round fit would have drawn (the
    per-round split chain continues where it left off — including through
    early-stop-masked rounds, which still split).

    ``member_sched`` is the (config.rounds, M) boolean membership schedule
    (``core.membership``); round t's row rides the scan inputs next to the
    round index, masks that round's weight fit (absent orgs get weight
    exactly 0.0 — so they also contribute exact zeros to the direction and
    to every eval combine), and is handed to ``fit_orgs`` for engine-side
    bookkeeping (DMS carry freezing). ``org_ids`` keys the weight-fit
    theta draws by org IDENTITY, so a reduced org set draws the same
    per-org jitter — together these make a masked fit bitwise-equal to
    fitting the reduced org set. ``None`` means every org attends every
    round (the pre-membership fast path, bit-identical to it).

    ``wfit_kwargs`` distributes the step-4 weight fit: a callable mapping
    this round's ``(preds, residual)`` to extra ``fit_weights`` kwargs (the
    block-sharded engine supplies a Gram-statistics ``objective_fn`` for
    the quadratic alice loss, a psum-combining ``combine_fn`` otherwise,
    plus ``grad_axes``; None keeps the replicated fit byte-identical).
    ``f0``
    overrides the cold-start ensemble init ``loss.init_prediction(y_in)``
    — the data-sharded engine computes it host-side from the FULL label
    vector, since e.g. a median init is not a per-shard reduction.

    ``config.residual_dtype="bf16"`` casts the privatized residual to
    bfloat16 BEFORE it crosses ``broadcast`` (the wire) and upcasts after:
    the identity broadcast of the vmap engines and the single-contributor
    psum of the mesh engine both reproduce the rounded values exactly, so
    all engines stay draw-for-draw identical under compression too. Alice's
    own weight-fit / line-search steps keep her full-precision residual —
    only what leaves her device is compressed.

    Everything else — residual, privacy, weight fit, eta line search,
    masked early stopping, history bookkeeping — is engine-independent and
    lives here exactly once. Returns ``(outs, init, carry_final)``; the
    full final carry is what ``GALResult.resume_state`` (and therefore the
    on-disk artifact) persists.
    """
    have_sched = member_sched is not None
    compress = _resid_wire_bytes(config) == 2

    def round_step(carry, xs):
        t, member_row = xs
        # membership off -> the literal pre-membership code path (mask=None
        # everywhere), so an unmasked fit stays bit-identical to before
        member = member_row if have_sched else None
        f, f_evals, key, active, state = carry
        key, k_round = jax.random.split(key)
        # 1. pseudo-residual  2. privatized broadcast
        residual = loss.residual(y_in, f)
        r_wire = apply_privacy(
            jax.random.fold_in(k_round, 13), residual, config.privacy,
            alpha=config.privacy_alpha,
            n_intervals=config.privacy_intervals,
        )
        if compress:
            r_wire = r_wire.astype(jnp.bfloat16)
        r_bcast = broadcast(r_wire)
        if r_bcast.dtype != residual.dtype:
            r_bcast = r_bcast.astype(residual.dtype)
        # 3. parallel local fits over the org axis
        state, params_out, preds, combine = fit_orgs(
            k_round, r_bcast, t, state, active, member)
        # 4. gradient assistance weights (masked over this round's live orgs)
        if config.use_weights and m > 1:
            w = fit_weights(
                jax.random.fold_in(k_round, 29), residual, preds,
                alice_loss, epochs=config.weight_epochs,
                lr=config.weight_lr, weight_decay=config.weight_decay,
                mask=member, org_ids=org_ids,
                **(wfit_kwargs(preds, residual)
                   if wfit_kwargs is not None else {}),
            )
        else:
            w = uniform_weights(m, mask=member)
        direction = combine(w, None)

        # 5. line-search eta   6. masked ensemble update
        # on a data-sharded mesh the loss value is global (psum'd) but its
        # AD gradient is shard-local; _grad_allreduce on eta restores the
        # global gradient the secant iteration needs
        eta_in = ((lambda e: _grad_allreduce(e, eta_grad_axes))
                  if eta_grad_axes else (lambda e: e))
        eta = line_search(
            lambda e: loss(y_in, f + eta_in(e) * direction),
            method=config.eta_method, x0=config.eta0,
        )
        eta_eff = jnp.where(active, eta, 0.0) if masked else eta
        f_new = f + eta_eff * direction

        outs = {"params": params_out, "eta": eta_eff, "w": w,
                "valid": active, "train_loss": loss(y_in, f_new)}
        new_evals = {}
        for name, (_, y_e) in evals_in.items():
            fe = f_evals[name] + eta_eff * combine(w, name)
            new_evals[name] = fe
            outs[f"{name}_loss"] = loss(y_e, fe)
            for mname, metric_fn in (metrics or {}).items():
                outs[f"{name}_{mname}"] = metric_fn(y_e, fe)
        new_active = (active & (jnp.abs(eta) >= config.eta_stop_threshold)
                      if masked else active)
        return (f_new, new_evals, key, new_active, state), outs

    if restore is None:
        f0v = loss.init_prediction(y_in) if f0 is None else f0
        f = jnp.broadcast_to(f0v, (n, k))
        f_evals = {
            name: jnp.broadcast_to(f0v, (y_e.shape[0], k))
            for name, (_, y_e) in evals_in.items()
        }
        active0 = jnp.asarray(True)
    else:
        f, f_evals_r, active0 = restore
        f_evals = {name: f_evals_r[name] for name in evals_in}
        active0 = jnp.asarray(active0)
    # on a resume the "init" row is the restored-carry loss, not round 0's —
    # the caller stitches the artifact's history in front and drops it
    init = {"train_loss": loss(y_in, f)}
    for name, (_, y_e) in evals_in.items():
        init[f"{name}_loss"] = loss(y_e, f_evals[name])
        for mname, metric_fn in (metrics or {}).items():
            init[f"{name}_{mname}"] = metric_fn(y_e, f_evals[name])
    carry0 = (f, f_evals, key, active0, state0)
    sched_rows = (jnp.ones((config.rounds - t0, m), bool)
                  if member_sched is None else member_sched[t0:])
    carry, outs = jax.lax.scan(round_step, carry0,
                               (jnp.arange(t0, config.rounds), sched_rows))
    return outs, init, carry


def _dms_org_round(model, lloss, key_m, x_m, ext_m, heads_m, rhist, t,
                   k_out, live_m=None):
    """One organization's Deep Model Sharing refit at 0-based round ``t``,
    replicating ``Organization._fit_round_dms`` with FIXED-shape buffers so
    the whole thing lives inside the scanned round step:

      * ``heads_m`` is the stacked ``(T, ...)`` head buffer — round ``t``'s
        fresh head (``init_head(fold_in(rng, t+1))``, the reference's
        1-based key) is written into slot ``t``;
      * ``rhist`` is the shared ``(T, N, K)`` broadcast-residual history;
      * the joint extractor+heads Adam refit optimizes the reference's
        per-slot objective — mean over rounds <= t of
        ``lloss(r^s, head_s(features(x)))`` — with slots beyond ``t``
        masked out, so their gradients are exactly zero and Adam leaves
        them untouched (the masked mean equals the reference's mean over
        its t live heads term for term).

    ``live_m`` is this org's (T,) membership column (None = always live):
    rounds the org skipped are dead slots — their heads stay zero, they are
    masked out of the refit objective exactly like not-yet-live slots, and
    the divisor counts attended rounds only. (The caller freezes the whole
    per-org state update when the org is absent THIS round; the column
    keeps its past absences out of every later refit.)

    Returns the refit ``(ext_m, heads_m)`` and this round's fitted values
    ``apply_head(heads_m[t], features(ext_m, x_m))``.
    """
    head_new = model.init_head(jax.random.fold_in(key_m, t + 1), k_out)
    heads_m = jax.tree_util.tree_map(
        lambda buf, hn: jax.lax.dynamic_update_index_in_dim(buf, hn, t, 0),
        heads_m, head_new)
    rounds_total = rhist.shape[0]
    mask = jnp.arange(rounds_total) <= t
    if live_m is not None:
        mask = mask & live_m
    n_live = jnp.maximum(jnp.sum(mask), 1) if live_m is not None else t + 1

    def objective(p):
        ext, heads = p
        feats = model.features({**ext, "head": None}, x_m)
        preds = jax.vmap(lambda h: model.apply_head(h, feats))(heads)
        # double-where: not-yet-live slots hold zero heads on zero
        # residuals, exactly where losses like sqrt(|r-f|) have an
        # unbounded derivative — masking only the OUTPUT would still
        # backprop 0 * inf = NaN into the shared extractor. Evaluating
        # dead slots at a fixed unit offset keeps their loss gradient
        # finite, the inner where zeroes their cotangent exactly, and the
        # outer where drops their (arbitrary) value from the sum; live
        # slots are untouched.
        mask3 = mask[:, None, None]
        safe_preds = jnp.where(mask3, preds, rhist + 1.0)
        per_slot = jax.vmap(lloss)(rhist, safe_preds)       # (T,)
        return jnp.sum(jnp.where(mask, per_slot, 0.0)) / n_live

    opt = adam(getattr(model, "lr", 1e-3))

    def step(carry, _):
        p, s = carry
        g = jax.grad(objective)(p)
        upd, s = opt.update(g, s, p)
        return (apply_updates(p, upd), s), None

    params = (ext_m, heads_m)
    (params, _), _ = jax.lax.scan(step, (params, opt.init(params)), None,
                                  length=getattr(model, "epochs", 100))
    ext_m, heads_m = params
    return ext_m, heads_m, _dms_apply(model, ext_m, heads_m, t, x_m)


def _dms_apply(model, ext_m, heads_m, t, x_m):
    """DMS prediction for one org: round ``t``'s head over the shared
    extractor's features (the traced twin of ``predict_round``)."""
    feats = model.features({**ext_m, "head": None}, x_m)
    head_t = jax.tree_util.tree_map(lambda l: l[t], heads_m)
    return model.apply_head(head_t, feats)


def _pad_rounds(resume_state: Dict[str, Any], groups, t0: int,
                rounds: int) -> Dict[str, Any]:
    """Grow a restored DMS carry from ``t0`` round slots to ``rounds``:
    the shared residual-history buffer pads on axis 0, every group's
    stacked head buffer on axis 1 (after the org axis). The padding is
    zeros — exactly what an uninterrupted ``rounds``-round fit would hold
    in its not-yet-live slots, so the masked per-slot DMS objective is
    unchanged term for term."""
    pad = rounds - t0
    state = dict(resume_state)
    if pad > 0 and "rhist" in state:
        rh = jnp.asarray(state["rhist"])
        state["rhist"] = jnp.pad(rh, ((0, pad),) + ((0, 0),) * (rh.ndim - 1))
        for gi, g in enumerate(groups):
            if not g.dms:
                continue
            gs = state[f"g{gi}"]
            state[f"g{gi}"] = {
                "extractor": gs["extractor"],
                "heads": jax.tree_util.tree_map(
                    lambda l: jnp.pad(
                        jnp.asarray(l),
                        ((0, 0), (0, pad)) + ((0, 0),) * (l.ndim - 2)),
                    gs["heads"]),
            }
    return state


def fit_grouped(rng: jax.Array, orgs: Sequence[Any], y: jnp.ndarray,
                loss: Loss, config: Any,
                eval_sets: Optional[Dict[str, tuple]] = None,
                metrics: Optional[Dict[str, Callable]] = None, *,
                plan: Optional[ExecutionPlan] = None,
                resume: Optional[Dict[str, Any]] = None,
                membership=None) -> Dict[str, Any]:
    """Run Algorithm 1 as one jitted scan over the planner's groups.

    Every group is a ``jax.vmap`` of its own model over its own stacked
    slice block, all inside the SAME traced round step; group fitted values
    are concatenated back into org order before the step-4 weight fit, so a
    heterogeneous GB–SVM mix, per-org local losses (ell_q or any traceable
    callable) and noisy orgs pay the same single host sync as the
    homogeneous case. Deep Model Sharing groups (paper Sec. 4.2/5) carry
    their shared extractor and stacked ``(T, ...)`` head buffer through the
    round scan (``_dms_org_round``); the Table-14 memory win is recorded in
    ``history["model_memories"]``. On a multi-device host where the device
    count divides every group size (and the plan is neither a single
    noiseless group — that case belongs to ``fit_shard``'s real
    collectives — nor stateful DMS), each group's stack is placed
    org-sharded along an "org" mesh axis and GSPMD partitions every
    group's fits across the devices.

    Returns a dict with host lists ``etas`` / ``weights``, the ``history``
    dict (losses/metrics as floats, the simulated per-round communication
    and model-memory ledgers as exact ints), device-side per-group stacked
    params ``group_params`` (leaves ``(T_valid, M_g, ...)``; DMS groups
    instead carry ``{"extractor": (M_g, ...), "heads": (M_g, T, ...)}``),
    the per-group ``group_dims`` / ``group_pads`` geometry, and —
    single-group fresh-fit plans only — the legacy ``params`` / ``dims`` /
    ``pad_to`` fields.

    ``resume`` (built by ``gal.fit(..., resume_from=...)``) restores the
    round-scan carry of a saved artifact — the ensemble state, per-eval
    carries, post-scan RNG key, early-stop flag, and (for DMS plans) the
    extractor/head/residual buffers, padded out to the new round count —
    and scans only rounds ``t_next .. config.rounds``; the returned dict
    then covers the NEW rounds only (the caller stitches).

    ``membership`` is the resolved bool (config.rounds, M) attendance
    schedule from ``core.membership.resolve_membership`` (None = all
    live): round t's row masks the weight fit (absent orgs get weight
    exactly 0.0), DMS orgs freeze their shared-extractor/head state in
    rounds they skip (their skipped slots stay dead in every later
    refit), and the per-round communication / model-memory ledgers count
    only the live orgs. On a resume the schedule must cover ALL rounds —
    rows before ``t_next`` are the collaboration's recorded history (they
    drive the DMS dead-slot masks), rows from ``t_next`` on are executed.
    """
    if plan is None:
        plan = plan_orgs(orgs, eval_sets)
    if not plan.compiled:
        raise ValueError(
            f"cannot compile this organization set: {plan.reason}")
    groups = plan.groups
    m = len(orgs)
    n, k = y.shape[0], y.shape[-1]
    alice_loss = lq_loss(config.alice_q)
    masked = config.eta_stop_threshold > 0.0

    mesh = None
    if (not plan.homogeneous and not plan.has_dms
            and grouped_mesh_eligible([g.size for g in groups])):
        mesh = make_org_mesh(len(jax.devices()))

    index_groups = [g.indices for g in groups]
    group_x, group_dims, group_pads = stack_groups(
        [org.x_train for org in orgs], index_groups, mesh=mesh)
    group_ids = [jnp.asarray(g.org_ids, jnp.uint32) for g in groups]
    group_pos = [jnp.asarray(g.indices, jnp.int32) for g in groups]
    inv_perm = jnp.asarray(plan.inverse_permutation, jnp.int32)
    org_ids_all = jnp.asarray([org.index for org in orgs], jnp.uint32)
    sched_np = None if membership is None else np.asarray(membership, bool)
    sched_in = None if sched_np is None else jnp.asarray(sched_np)

    y_in = y if mesh is None else jax.device_put(y, org_replicated(mesh))
    eval_stacks = {}
    if eval_sets:
        for name, (xs_e, y_e) in eval_sets.items():
            stacks_e, _, _ = stack_groups(list(xs_e), index_groups,
                                          pad_tos=group_pads, mesh=mesh)
            y_e_in = (y_e if mesh is None
                      else jax.device_put(y_e, org_replicated(mesh)))
            eval_stacks[name] = (tuple(stacks_e), y_e_in)

    t0 = 0
    key0 = rng
    resume_in = None
    if resume is not None:
        t0 = int(resume["t_next"])
        key0 = jnp.asarray(resume["key"])
        resume_in = {
            "f": jnp.asarray(resume["f"]),
            "f_evals": {nm: jnp.asarray(v)
                        for nm, v in resume.get("f_evals", {}).items()},
            "active": jnp.asarray(resume["active"]),
            "state": _pad_rounds(resume.get("state", {}) or {}, groups,
                                 t0, config.rounds),
        }
        if mesh is not None:
            resume_in = jax.tree_util.tree_map(
                lambda a: jax.device_put(a, org_replicated(mesh)), resume_in)
    if mesh is not None:
        org_ids_all = jax.device_put(org_ids_all, org_replicated(mesh))
        if sched_in is not None:
            sched_in = jax.device_put(sched_in, org_replicated(mesh))

    def run(key, y_dev, xg_in, evals_in, res_in, sched_dev, ids_dev):
        # DMS carry: one shared (T, N, K) residual-history buffer plus each
        # DMS group's extractor stack and (M_g, T, ...) head buffers. The
        # extractor inits replicate the reference exactly: round 0's
        # k_round is split(rng)[1], and org m's init key fold_in(., index).
        # On a resume the carry arrives fully formed from the artifact.
        state0: Dict[str, Any] = {} if res_in is None else res_in["state"]
        restore = (None if res_in is None
                   else (res_in["f"], res_in["f_evals"], res_in["active"]))
        if plan.has_dms and res_in is None:
            k_round0 = jax.random.split(key)[1]
            state0["rhist"] = jnp.zeros((config.rounds, n, k), y_dev.dtype)
            for gi, g in enumerate(groups):
                if not g.dms:
                    continue
                keys0 = jax.vmap(lambda i: jax.random.fold_in(
                    k_round0, i))(group_ids[gi])

                def init_ext(key_m, x_m, model=g.model):
                    full = model.init(key_m, x_m, k)
                    return {kk: v for kk, v in full.items() if kk != "head"}

                head_spec = jax.eval_shape(
                    lambda kk, model=g.model: model.init_head(kk, k),
                    jax.random.PRNGKey(0))
                state0[f"g{gi}"] = {
                    "extractor": jax.vmap(init_ext)(keys0, xg_in[gi]),
                    "heads": jax.tree_util.tree_map(
                        lambda s: jnp.zeros(
                            (g.size, config.rounds) + s.shape, s.dtype),
                        head_spec),
                }

        def fit_orgs(k_round, r_bcast, t, state, active, member):
            new_state = dict(state)
            if plan.has_dms:
                new_state["rhist"] = jax.lax.dynamic_update_index_in_dim(
                    state["rhist"], r_bcast, t, 0)
            # one vmapped model PER GROUP, all in the same traced step
            params_g, preds_g, dms_g = [], [], {}
            for gi, g in enumerate(groups):
                keys = jax.vmap(
                    lambda i: jax.random.fold_in(k_round, i))(group_ids[gi])
                if g.dms:
                    gs = state[f"g{gi}"]

                    if sched_dev is None:
                        def dms_one(key_m, x_m, ext_m, heads_m,
                                    model=g.model, lloss=g.local_loss):
                            return _dms_org_round(
                                model, lloss, key_m, x_m, ext_m, heads_m,
                                new_state["rhist"], t, k)

                        ext_new, heads_new, preds_t = jax.vmap(dms_one)(
                            keys, xg_in[gi], gs["extractor"], gs["heads"])
                    else:
                        # each org's (T,) membership column rides the vmap:
                        # its skipped rounds are dead head slots, masked
                        # out of every later refit objective
                        live_g = sched_dev[:, group_pos[gi]].T    # (Mg, T)

                        def dms_one(key_m, x_m, ext_m, heads_m, live_m,
                                    model=g.model, lloss=g.local_loss):
                            return _dms_org_round(
                                model, lloss, key_m, x_m, ext_m, heads_m,
                                new_state["rhist"], t, k, live_m)

                        ext_new, heads_new, preds_t = jax.vmap(dms_one)(
                            keys, xg_in[gi], gs["extractor"], gs["heads"],
                            live_g)
                        # absent THIS round: the whole per-org DMS state
                        # update is frozen — the skipped slot's head stays
                        # zero and the shared extractor is untouched,
                        # exactly as the reference loop's skip would leave
                        keep = member[group_pos[gi]]

                        def _frz(a, b, keep=keep):
                            shape = keep.shape + (1,) * (a.ndim - 1)
                            return jnp.where(keep.reshape(shape), a, b)

                        ext_new = jax.tree_util.tree_map(
                            _frz, ext_new, gs["extractor"])
                        heads_new = jax.tree_util.tree_map(
                            _frz, heads_new, gs["heads"])
                    new_state[f"g{gi}"] = {"extractor": ext_new,
                                           "heads": heads_new}
                    dms_g[gi] = new_state[f"g{gi}"]
                    params_t = ()      # state-carried; no per-round output
                else:
                    def fit_one(key_m, x_m, model=g.model,
                                lloss=g.local_loss):
                        params = model.fit(key_m, x_m, r_bcast, lloss)
                        return params, model.apply(params, x_m)

                    params_t, preds_t = jax.vmap(fit_one)(keys, xg_in[gi])
                if g.noise_sigma > 0.0:
                    # training-stage output noise, reference-engine keys
                    # (fold_in(org_key, 777), see Organization.fit_round)
                    preds_t = preds_t + g.noise_sigma * jax.vmap(
                        lambda kk: jax.random.normal(
                            jax.random.fold_in(kk, 777), (n, k)))(keys)
                params_g.append(params_t)
                preds_g.append(preds_t)
            if masked and plan.has_dms:
                # early-stopped rounds must leave the DMS carry untouched,
                # exactly as the reference loop's `break` would
                new_state = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(active, a, b), new_state, state)
                for gi in dms_g:
                    dms_g[gi] = new_state[f"g{gi}"]
            # concatenate group blocks back into ORG order for step 4
            preds = jnp.concatenate(preds_g, axis=0)[inv_perm]   # (M, N, K)

            def combine(w, name):
                if name is None:
                    return jnp.einsum("m,mnk->nk", w, preds)
                out = None
                for gi, g in enumerate(groups):
                    if g.dms:
                        gs = dms_g[gi]
                        pe = jax.vmap(
                            lambda e, h, x, model=g.model: _dms_apply(
                                model, e, h, t, x)
                        )(gs["extractor"], gs["heads"], evals_in[name][0][gi])
                    else:
                        pe = jax.vmap(g.model.apply)(params_g[gi],
                                                     evals_in[name][0][gi])
                    if g.noise_sigma > 0.0:
                        # prediction-stage noise, engine-independent keys
                        # (fold_in(PRNGKey(index), t), see predict_round)
                        pkeys = jax.vmap(lambda i: jax.random.fold_in(
                            jax.random.PRNGKey(i), t))(group_ids[gi])
                        pe = pe + g.noise_sigma * jax.vmap(
                            lambda kk: jax.random.normal(
                                kk, pe.shape[1:]))(pkeys)
                    part = jnp.einsum("m,mnk->nk", w[group_pos[gi]], pe)
                    out = part if out is None else out + part
                return out

            return new_state, tuple(params_g), preds, combine

        return _run_rounds(key, y_dev, evals_in, lambda r: r, fit_orgs,
                           loss=loss, config=config, m=m, n=n, k=k,
                           masked=masked, metrics=metrics,
                           alice_loss=alice_loss, state0=state0, t0=t0,
                           restore=restore, member_sched=sched_dev,
                           org_ids=ids_dev)

    outs, init, carry = jax.jit(run)(key0, y_in, tuple(group_x),
                                     eval_stacks, resume_in, sched_in,
                                     org_ids_all)
    state_final = carry[4]
    dms_flags = [False] * m
    for g in groups:
        for i in g.indices:
            dms_flags[i] = g.dms
    eval_ns = [int(y_e.shape[0])
               for (_, y_e) in (eval_sets or {}).values()]
    rb = _resid_wire_bytes(config)
    if sched_np is None:
        bcast_b, gather_b = gal_round_bytes(n, k, m, eval_ns,
                                            resid_dtype_bytes=rb)
    else:
        from repro.core.membership import membership_comm_ledger
        bcast_l, gather_l = membership_comm_ledger(sched_np, n, k, eval_ns,
                                                   resid_dtype_bytes=rb)
        bcast_b, gather_b = bcast_l[t0:], gather_l[t0:]
    single = len(groups) == 1 and not plan.has_dms
    out = _finalize(outs, init, masked, config.rounds - t0,
                    dims=group_dims[0] if single else None,
                    pad_to=group_pads[0] if single else None,
                    comm={"comm_broadcast_bytes": bcast_b,
                          "comm_gather_bytes": gather_b,
                          "model_memories": gal_model_memories(
                              config.rounds, dms_flags,
                              membership=sched_np)[t0:]})
    if sched_np is not None:
        # executed rows only (early-stop trimmed), host bools in org order
        out["membership"] = sched_np[t0:t0 + len(out["etas"])].tolist()
    group_params = list(out["params"])            # tuple trimmed by _finalize
    for gi, g in enumerate(groups):
        if g.dms:
            # the final carry state IS the fitted DMS ensemble: the shared
            # extractor after the last live round plus every round's head
            group_params[gi] = state_final[f"g{gi}"]
    out["params"] = group_params[0] if single else None
    out["group_params"] = group_params
    out["group_dims"] = group_dims
    out["group_pads"] = group_pads
    out["plan"] = plan
    out["mesh_devices"] = 0 if mesh is None else len(jax.devices())
    # the final round-scan carry, verbatim: what save_artifact persists and
    # a later fit(resume_from=...) restores. The key has been split once
    # per scanned round (masked rounds included), so resuming continues
    # the exact per-round draw chain of an uninterrupted longer fit.
    out["resume"] = {"t_next": config.rounds, "f": carry[0],
                     "f_evals": carry[1], "key": carry[2],
                     "active": carry[3], "state": state_final}
    return out


def fit_scan(rng: jax.Array, orgs: Sequence[Any], y: jnp.ndarray, loss: Loss,
             config: Any, eval_sets: Optional[Dict[str, tuple]] = None,
             metrics: Optional[Dict[str, Callable]] = None, *,
             plan: Optional[ExecutionPlan] = None,
             resume: Optional[Dict[str, Any]] = None,
             membership=None) -> Dict[str, Any]:
    """The legacy homogeneous fast path: ``fit_grouped`` on a single-group
    plan (one model vmapped over one org stack). Kept as the named engine
    behind ``GALConfig.engine="scan"``; the dispatch in ``gal.fit`` enforces
    the single-noiseless-group contract before calling it."""
    return fit_grouped(rng, orgs, y, loss, config, eval_sets, metrics,
                       plan=plan, resume=resume, membership=membership)


class _DataAxisLoss:
    """Loss proxy for the data-sharded engine: the global mean loss is the
    psum of the equal shards' local means; the pseudo-residual stays an
    elementwise (hence shard-local) map. ``init_prediction`` is NOT a
    per-shard reduction (think median inits) — the engine computes it
    host-side from the full label vector and threads it through
    ``_run_rounds(f0=...)``, so the proxy never evaluates it in-trace."""

    def __init__(self, base: Loss, axis: str, shards: int):
        self.base, self.axis, self.shards = base, axis, shards

    def __call__(self, y, f):
        return jax.lax.psum(self.base(y, f), self.axis) / self.shards

    def residual(self, y, f):
        return self.base.residual(y, f)

    def init_prediction(self, y):
        return self.base.init_prediction(y)


def _shard_program(rng: jax.Array, orgs: Sequence[Any], y: jnp.ndarray,
                   loss: Loss, config: Any,
                   eval_sets: Optional[Dict[str, tuple]] = None,
                   metrics: Optional[Dict[str, Callable]] = None,
                   resume: Optional[Dict[str, Any]] = None,
                   membership=None) -> Dict[str, Any]:
    """Build (but do not run) the org-sharded engine's compiled program:
    placement, shard_map wrapping, jit, and the operand list. ``fit_shard``
    executes it; ``lower_shard_round`` hands its lowered HLO to the
    roofline tools so the collective traffic the compiler actually emits
    can be reconciled with the protocol ledger's ints."""
    from jax.sharding import NamedSharding

    m = len(orgs)
    data_shards = int(getattr(config, "data_shards", 1) or 1)
    if not org_mesh_eligible(m, data_shards):
        raise ValueError(
            f"engine='shard' needs an org mesh: {m} orgs must divide the "
            f"org-axis device count or be divisible by it for block "
            f"placement ({jax.device_count()} devices / {data_shards} data "
            f"shard(s), multi-device host required)")
    mesh = make_org_mesh(m, data_shards)
    bsz = org_block_size(m, data_shards)
    has_data = data_shards > 1
    model = orgs[0].model
    local_loss = orgs[0].local_loss
    n, k = y.shape[0], y.shape[-1]
    if has_data:
        if config.privacy:
            raise ValueError(
                "data_shards > 1 cannot run a privatized broadcast: the "
                "per-shard noise draws would not match the protocol's "
                "single (N, K) draw")
        if not getattr(model, "data_parallel", False):
            raise ValueError(
                f"data_shards > 1 needs a data-parallel local model "
                f"(fit accepting data_axis); {type(model).__name__} "
                f"does not declare data_parallel")
        if n % data_shards:
            raise ValueError(
                f"data_shards={data_shards} must divide the train rows "
                f"({n}) into equal shards")
    n_local = n // data_shards
    alice_loss = lq_loss(config.alice_q)
    masked = config.eta_stop_threshold > 0.0
    loss_in = _DataAxisLoss(loss, "data", data_shards) if has_data else loss
    alice_in = (_DataAxisLoss(alice_loss, "data", data_shards)
                if has_data else alice_loss)

    # org-major placement: a block of bsz org slices / ids per device (one
    # each under one-to-one placement), Alice state replicated; with a data
    # axis, each org's rows are additionally split across it
    x_stack, dims = pad_and_stack_sharded(
        [org.x_train for org in orgs], mesh, block_size=bsz,
        shard_data=has_data)
    pad_to = int(x_stack.shape[-1]) if x_stack.ndim == 3 else None
    org_ids = jax.device_put(
        jnp.asarray([org.index for org in orgs], jnp.uint32),
        org_stack_sharding(mesh, 1, block_size=bsz))
    # Alice's full id vector + the membership schedule ride replicated:
    # the weight fit is her step, not a per-device one
    ids_full = jax.device_put(
        jnp.asarray([org.index for org in orgs], jnp.uint32),
        org_replicated(mesh))
    sched_np = None if membership is None else np.asarray(membership, bool)
    sched_in = (None if sched_np is None
                else jax.device_put(jnp.asarray(sched_np),
                                    org_replicated(mesh)))
    y_spec = P("data") if has_data else P()
    y_dev = jax.device_put(y, NamedSharding(mesh, y_spec))
    eval_stacks, eval_in_specs = {}, {}
    if eval_sets:
        for name, (xs_e, y_e) in eval_sets.items():
            # eval slices stay replicated over "data": the prediction
            # stage is per-org, not per-row-shard
            xe_stack, _ = pad_and_stack_sharded(list(xs_e), mesh,
                                                pad_to=pad_to,
                                                block_size=bsz)
            eval_stacks[name] = (xe_stack,
                                 jax.device_put(y_e, org_replicated(mesh)))
            eval_in_specs[name] = (P("org"), P())

    t0 = 0
    key0 = rng
    extras: Dict[str, Any] = {}
    extras_specs: Dict[str, Any] = {}
    if has_data:
        # init ensemble from the FULL label vector, host-side (a median
        # init is not a per-shard reduction); rides the mesh replicated
        extras["f0"] = jnp.asarray(loss.init_prediction(y))
        extras_specs["f0"] = P()
    if resume is not None:
        t0 = int(resume["t_next"])
        key0 = jnp.asarray(resume["key"])
        # the restored carry is org-independent: replicate it on the mesh
        # (the ensemble state shards over "data" when that axis exists)
        extras["resume"] = {
            "f": jax.device_put(jnp.asarray(resume["f"]),
                                NamedSharding(mesh, y_spec)),
            "f_evals": {nm: jax.device_put(
                jnp.asarray(resume.get("f_evals", {})[nm]),
                org_replicated(mesh)) for nm in eval_stacks},
            "active": jax.device_put(jnp.asarray(resume["active"]),
                                     org_replicated(mesh))}
        extras_specs["resume"] = {
            "f": y_spec,
            "f_evals": {name: P() for name in eval_stacks},
            "active": P()}

    def run(key, y_in, x_in, ids_in, evals_in, sched_dev, ids_all, extra):
        pos = jax.lax.axis_index("org")

        def broadcast(r_wire):
            # step 2 as a REAL collective: only Alice's device row (org
            # position 0) contributes, so the psum equals her privatized
            # residual exactly while crossing every device boundary
            return jax.lax.psum(
                jnp.where(pos == 0, r_wire, jnp.zeros_like(r_wire)), "org")

        wfit = None
        if bsz == 1 and not has_data:
            my_x = x_in[0]             # this device's org slice (N, d_max)
            my_id = ids_in[0]

            def fit_orgs(k_round, r_bcast, t, state, active, member):
                del t, active, member  # single noiseless fresh-fit group:
                # stateless, and membership acts purely through the step-4
                # weight mask (w[pos] == 0.0 zeroes this device's psum term)
                # THIS device's local fit only (the scan engine's vmap axis
                # became the mesh axis); RNG key identical to other engines
                params_m = model.fit(jax.random.fold_in(k_round, my_id),
                                     my_x, r_bcast, local_loss)
                pred_m = model.apply(params_m, my_x)          # (N, K)
                # step 4's inputs: fitted values gathered back to Alice
                preds = jax.lax.all_gather(pred_m, "org")     # (M, N, K)

                def combine(w, name):
                    # weighted org-sum as a psum over the mesh axis
                    out_m = pred_m if name is None \
                        else model.apply(params_m, evals_in[name][0][0])
                    return jax.lax.psum(w[pos] * out_m, "org")

                params_out = jax.tree_util.tree_map(lambda l: l[None],
                                                    params_m)
                return state, params_out, preds, combine
        else:
            # block placement / data axis: this device fits its WHOLE block
            # of bsz orgs (vmap inside the manual region), combines are a
            # block-local einsum + psum, and the step-4 weight fit is
            # distributed — each device optimizes against its own block of
            # fitted values, with the per-step theta gradient psummed back
            # to the replicated trajectory (see weights.fit_weights)
            def fit_orgs(k_round, r_bcast, t, state, active, member):
                del t, active, member
                keys = jax.vmap(
                    lambda i: jax.random.fold_in(k_round, i))(ids_in)

                def fit_one(key_m, x_m):
                    if has_data:
                        p = model.fit(key_m, x_m, r_bcast, local_loss,
                                      data_axis="data")
                    else:
                        p = model.fit(key_m, x_m, r_bcast, local_loss)
                    return p, model.apply(p, x_m)

                params_b, preds_b = jax.vmap(fit_one)(keys, x_in)
                # (M, N_local, K): the protocol's fitted-value gather, now
                # of block-local stacks
                preds = jax.lax.all_gather(preds_b, "org", tiled=True)

                def combine(w, name):
                    out_b = (preds_b if name is None
                             else jax.vmap(model.apply)(params_b,
                                                        evals_in[name][0]))
                    wl = jax.lax.dynamic_slice(w, (pos * bsz,), (bsz,))
                    return jax.lax.psum(
                        jnp.einsum("b,bnk->nk", wl, out_b), "org")

                return state, params_b, preds, combine

            grad_axes = ((("org",) if bsz > 1 else ())
                         + (("data",) if has_data else ()))

            def wfit(preds, residual):
                if bsz == 1:
                    # one org per device, rows sharded: the replicated
                    # einsum stands, only the loss mean reduces over "data"
                    return {"grad_axes": grad_axes}
                blk = jax.lax.dynamic_slice(
                    preds, (pos * bsz, 0, 0), (bsz,) + preds.shape[1:])
                if getattr(alice_in, "q", None) == 2.0:
                    # quadratic alice loss (the alice_q=2 default): the
                    # objective  mean (r - sum_m w_m p_m)^2  factors through
                    # per-block Gram statistics computed ONCE per round,
                    #   G_blk = blk . preds^T   (B, M)
                    #   c_blk = blk . r         (B,)
                    # so each of the 100 Adam epochs costs O(B*M) flops and
                    # a single (M,) gradient psum — no (N, K) tensor is
                    # touched, let alone reduced, inside the epoch loop.
                    # Each device's value is its block's partial sum; the
                    # explicit grad psum in fit_weights reassembles the
                    # exact replicated gradient (Adam never reads the
                    # value). Masked orgs still contribute exact zeros:
                    # w == 0.0 annihilates their rows and columns.
                    g_blk = jnp.einsum("bnk,mnk->bm", blk, preds)
                    c_blk = jnp.einsum("bnk,nk->b", blk, residual)
                    rss = jnp.sum(jnp.square(residual))
                    denom = residual.size

                    def objective_fn(w):
                        wl = jax.lax.dynamic_slice(w, (pos * bsz,), (bsz,))
                        quad = jnp.dot(wl, g_blk @ w) \
                            - 2.0 * jnp.dot(wl, c_blk)
                        return (quad + rss) / denom

                    return {"m": m, "objective_fn": objective_fn,
                            "grad_axes": grad_axes}

                def combine_fn(w):
                    wl = jax.lax.dynamic_slice(w, (pos * bsz,), (bsz,))
                    local = jnp.einsum("b,bnk->nk", wl, blk)
                    # forward: the exact psum'd combination; backward: AD
                    # sees only the local block's path (the other blocks
                    # enter as a stop_gradient constant), so the epoch's
                    # second (N, K) all-reduce — psum's transpose — never
                    # exists. The explicit (M,) grad psum in fit_weights
                    # reassembles the identical global gradient.
                    total = jax.lax.psum(jax.lax.stop_gradient(local), "org")
                    return total - jax.lax.stop_gradient(local) + local

                return {"m": m, "combine_fn": combine_fn,
                        "grad_axes": grad_axes}

        res_in = extra.get("resume")
        restore = (None if res_in is None
                   else (res_in["f"], res_in["f_evals"], res_in["active"]))
        return _run_rounds(key, y_in, evals_in, broadcast, fit_orgs,
                           loss=loss_in, config=config, m=m, n=n_local, k=k,
                           masked=masked, metrics=metrics,
                           alice_loss=alice_in, t0=t0, restore=restore,
                           member_sched=sched_dev, org_ids=ids_all,
                           wfit_kwargs=wfit, f0=extra.get("f0"),
                           eta_grad_axes=(("data",) if has_data else ()))

    # everything in the scalar bundle is replicated (collectives + identical
    # per-device programs on replicated inputs); only the per-round params
    # keep an org axis, split block-wise over the mesh
    out_specs = {"params": P(None, "org"), "eta": P(), "w": P(),
                 "valid": P(), "train_loss": P()}
    for name in eval_stacks:
        out_specs[f"{name}_loss"] = P()
        for mname in (metrics or {}):
            out_specs[f"{name}_{mname}"] = P()
    # the returned carry is fully replicated — ensemble state, per-eval
    # carries, key and early-stop flag ride the collectives — except the
    # train-set ensemble, which shards over "data" when that axis exists;
    # the state slot is the empty tuple (shard plans are stateless)
    carry_specs = (y_spec, {name: P() for name in eval_stacks}, P(), P(), ())
    x_spec = P("org", "data") if has_data else P("org")
    in_specs = [P(), y_spec, x_spec, P("org"), eval_in_specs, P(), P(),
                extras_specs]
    operands = [key0, y_dev, x_stack, org_ids, eval_stacks, sched_in,
                ids_full, extras]
    run_sharded = shard_map(
        run, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(out_specs, P(), carry_specs),
        check_rep=False,
    )
    return {"jit": jax.jit(run_sharded), "operands": operands,
            "mesh": mesh, "dims": dims, "pad_to": pad_to,
            "sched_np": sched_np, "t0": t0, "n": n, "k": k, "m": m,
            "eval_ns": [int(y_e.shape[0])
                        for (_, y_e) in eval_stacks.values()],
            "block_size": bsz, "data_shards": data_shards,
            "masked": masked}


def fit_shard(rng: jax.Array, orgs: Sequence[Any], y: jnp.ndarray, loss: Loss,
              config: Any, eval_sets: Optional[Dict[str, tuple]] = None,
              metrics: Optional[Dict[str, Callable]] = None,
              resume: Optional[Dict[str, Any]] = None,
              membership=None) -> Dict[str, Any]:
    """Run Algorithm 1 org-sharded across devices (see the module docstring).

    Same contract as ``fit_scan`` — the T-round ``lax.scan``, the single
    host sync, and the returned dict are identical — but the org axis is a
    real device mesh instead of a ``vmap``: an org's padded slice,
    per-round params, and fitted values never leave its device except
    through Alg. 1's three collectives (residual broadcast, fitted-value
    gather, weighted direction psum). Two placements (see
    ``launch.mesh.org_mesh_eligible``): one-to-one — one org per device —
    and block — a contiguous block of ``M // device_count`` orgs per
    device, fitted by a vmap inside the manual region, with the step-4
    weight fit distributed over the blocks. ``GALConfig(data_shards=...)``
    adds a second "data" mesh axis splitting each org's N rows (the
    per-round weight fit and eta line search reduce across it);
    ``GALConfig(residual_dtype="bf16")`` halves the broadcast wire width.
    The returned history carries the per-round communication ledger
    (``comm_broadcast_bytes`` / ``comm_gather_bytes``, paper Table-14
    convention: Alice already holds her own residual copy, every org —
    Alice included — ships its fitted values; the broadcast column counts
    the compressed wire dtype).

    ``resume`` restores an artifact's round-scan carry (replicated across
    the mesh — the ensemble state and RNG chain are org-independent) and
    scans rounds ``t_next .. config.rounds`` only, exactly as
    ``fit_grouped`` does; shard plans are stateless (no DMS carry).

    ``membership`` (resolved bool (rounds, M) schedule or None) rides the
    mesh replicated: an absent org's device still fits — the collectives
    have static shapes — but its assistance weight is exactly 0.0, so its
    psum contribution is exact zeros and the recorded per-round wire
    ledger counts only the live orgs."""
    prog = _shard_program(rng, orgs, y, loss, config, eval_sets, metrics,
                          resume, membership)
    outs, init, carry = prog["jit"](*prog["operands"])
    # per-round ledger of the collectives above, from the (static) operand
    # shapes — exact ints, Table-14 convention (Alice already holds her
    # residual copy; all M orgs ship fitted values for the train AND eval
    # prediction stages). gal_round_bytes is the one formula every
    # engine's ledger comes from, so the history is engine-independent.
    n, k, m = prog["n"], prog["k"], prog["m"]
    t0, sched_np, eval_ns = prog["t0"], prog["sched_np"], prog["eval_ns"]
    rb = _resid_wire_bytes(config)
    if sched_np is None:
        bcast_b, gather_b = gal_round_bytes(n, k, m, eval_ns,
                                            resid_dtype_bytes=rb)
    else:
        from repro.core.membership import membership_comm_ledger
        bcast_l, gather_l = membership_comm_ledger(sched_np, n, k, eval_ns,
                                                   resid_dtype_bytes=rb)
        bcast_b, gather_b = bcast_l[t0:], gather_l[t0:]
    out = _finalize(outs, init, prog["masked"], config.rounds - t0,
                    prog["dims"], prog["pad_to"],
                    comm={"comm_broadcast_bytes": bcast_b,
                          "comm_gather_bytes": gather_b,
                          "model_memories": gal_model_memories(
                              config.rounds, [False] * m,
                              membership=sched_np)[t0:]})
    if sched_np is not None:
        out["membership"] = sched_np[t0:t0 + len(out["etas"])].tolist()
    out["resume"] = {"t_next": config.rounds, "f": carry[0],
                     "f_evals": carry[1], "key": carry[2],
                     "active": carry[3], "state": {}}
    return out


def lower_shard_round(rng: jax.Array, orgs: Sequence[Any], y: jnp.ndarray,
                      loss: Loss, config: Any,
                      eval_sets: Optional[Dict[str, tuple]] = None,
                      metrics: Optional[Dict[str, Callable]] = None):
    """Lower — without executing — the exact compiled program ``fit_shard``
    would run, returning the ``jax.stages.Lowered`` handle. Roofline's
    ``collective_bytes_from_hlo`` / ``hlo_stats.analyze`` read its HLO
    (``.as_text()``) to attribute collective traffic; see
    ``roofline.analysis.gal_shard_round_collectives`` for the mapping from
    those per-partition HLO bytes to the protocol ledger's ints."""
    prog = _shard_program(rng, orgs, y, loss, config, eval_sets, metrics)
    return prog["jit"].lower(*prog["operands"])


def grouped_predict(groups: Sequence[Any], group_params: Sequence[Any],
                    group_dims: Sequence[Sequence[int]],
                    group_pads: Sequence[Optional[int]],
                    etas: Sequence[float], weights: Sequence[jnp.ndarray],
                    f0: jnp.ndarray, xs: Sequence[jnp.ndarray],
                    t_max: int) -> jnp.ndarray:
    """Prediction stage for a planner-grouped ensemble.

    Per group: one nested (rounds x group-orgs) vmap of the group's model
    over its stacked slices, contracted with that group's slice of the
    assistance weights in a single einsum — then summed over groups. Deep
    Model Sharing groups featurize each org's slice ONCE through the final
    shared extractor and read round t's head from the stacked ``(T, ...)``
    head axis (exactly ``predict_round``'s final-state replay). Noisy
    groups add the engine-independent prediction-stage noise
    (``fold_in(PRNGKey(org.index), t)``, matching
    ``Organization.predict_round``), so grouped predictions equal the
    Python reference assembly draw for draw.
    """
    n = xs[0].shape[0]
    k = f0.shape[-1]
    f = jnp.broadcast_to(f0, (n, k))
    if t_max == 0:
        return f
    etas_t = jnp.asarray(etas[:t_max], jnp.float32)
    w_t = jnp.stack(list(weights[:t_max]))                       # (T, M)
    out = f
    for gi, g in enumerate(groups):
        xs_g = [xs[i] for i in g.indices]
        if xs_g[0].ndim == 2:
            # the zero-pad would silently swallow mis-sized/mis-ordered
            # slices that the reference engine rejects — keep that net
            got = [int(x.shape[-1]) for x in xs_g]
            if got != [int(d) for d in group_dims[gi]]:
                raise ValueError(
                    f"prediction slice widths {got} do not match the "
                    f"fitted per-org widths {list(group_dims[gi])} of "
                    f"group {g.describe()} (check org order)")
        x_stack, _ = pad_and_stack(xs_g, pad_to=group_pads[gi])
        if g.dms:
            gp = group_params[gi]

            def dms_preds(ext_m, heads_m, x_m, model=g.model):
                # features once per org; every round's head off the stack
                feats = model.features({**ext_m, "head": None}, x_m)
                return jax.vmap(
                    lambda h: model.apply_head(h, feats)
                )(jax.tree_util.tree_map(lambda l: l[:t_max], heads_m))

            preds = jnp.swapaxes(jax.vmap(dms_preds)(
                gp["extractor"], gp["heads"], x_stack), 0, 1)    # (T,Mg,N,K)
        else:
            params_t = jax.tree_util.tree_map(lambda l: l[:t_max],
                                              group_params[gi])
            preds = jax.vmap(
                lambda p, model=g.model: jax.vmap(model.apply)(p, x_stack)
            )(params_t)                                          # (T,Mg,N,K)
        if g.noise_sigma > 0.0:
            ids = jnp.asarray(g.org_ids, jnp.uint32)
            noise = jax.vmap(lambda t: jax.vmap(
                lambda i: jax.random.normal(
                    jax.random.fold_in(jax.random.PRNGKey(i), t), (n, k))
            )(ids))(jnp.arange(t_max))
            preds = preds + g.noise_sigma * noise
        out = out + jnp.einsum("t,tm,tmnk->nk", etas_t,
                               w_t[:, jnp.asarray(g.indices)], preds)
    return out
