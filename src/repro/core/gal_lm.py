"""GAL at LM scale: the paper's protocol with assigned-architecture orgs.

Alice holds next-token labels; each organization holds a private *view* of
the token stream (vertical split, e.g. vocab factorization or a modality) and
a private sequence model (any repro.configs architecture). Per round:

  1. Alice computes the pseudo-residual r = onehot(y) - softmax(F) in logit
     space with the fused Pallas kernel (repro.kernels.residual_xent).
  2. r is broadcast — dense (paper-faithful) or top-K compressed
     (beyond-paper transport; see train.steps.gal_residual_topk_loss).
  3. Each org runs `local_steps` SGD/AdamW steps of its architecture on the
     residual-fit objective.
  4. Alice fits assistance weights on the simplex and line-searches eta.
  5. F <- F + eta * sum_m w_m f_m.

This module is deliberately *small*: it composes repro.core (weights,
line-search), repro.train.steps (losses) and repro.models (architectures).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.losses import CrossEntropyLoss
from repro.core.weights import fit_weights, uniform_weights
from repro.kernels.ops import residual_xent
from repro.models import transformer as tfm
from repro.optim.lbfgs import line_search
from repro.optim.optimizers import adamw, apply_updates
from repro.train.steps import make_train_step


def compute_residual(labels: jnp.ndarray, ensemble_logits: jnp.ndarray,
                     use_kernel: bool = True) -> jnp.ndarray:
    """r = onehot(labels) - softmax(F): (B, S) x (B, S, V) -> (B, S, V)."""
    return residual_xent(ensemble_logits, labels, use_kernel=use_kernel)


def topk_compress(residual: jnp.ndarray, k: int):
    """Keep the k largest-|r| entries per token: (vals, idx)."""
    vals, idx = jax.lax.top_k(jnp.abs(residual), k)
    vals = jnp.take_along_axis(residual, idx, axis=-1)
    return vals, idx


@dataclass
class LMOrganization:
    """One org: private token view + private architecture."""
    index: int
    cfg: ModelConfig
    view_fn: Callable[[jnp.ndarray], jnp.ndarray]   # tokens -> private view
    params: Any = None
    opt_state: Any = None
    _train_step: Any = None

    def init(self, rng: jax.Array, lr: float = 1e-3):
        self.params = tfm.init_params(rng, self.cfg)
        self._train_step, opt = make_train_step(
            self.cfg, "gal_residual", lr=lr, weight_decay=0.0)
        self.opt_state = opt.init(self.params)

    def fit_round(self, rng: jax.Array, tokens: jnp.ndarray,
                  residual: jnp.ndarray, local_steps: int = 10) -> jnp.ndarray:
        """Fit the broadcast residual; return f_m(x_m) on the batch."""
        view = self.view_fn(tokens)
        batch = {"tokens": view, "residual": residual}
        for _ in range(local_steps):
            self.params, self.opt_state, _ = self._train_step(
                self.params, self.opt_state, batch)
        logits, _ = tfm.apply(self.params, self.cfg, view)
        return logits.astype(jnp.float32)

    def predict(self, tokens: jnp.ndarray) -> jnp.ndarray:
        logits, _ = tfm.apply(self.params, self.cfg, self.view_fn(tokens))
        return logits.astype(jnp.float32)


@dataclass
class GALLMResult:
    orgs: List[LMOrganization]
    f0: jnp.ndarray
    etas: List[float] = field(default_factory=list)
    weights: List[jnp.ndarray] = field(default_factory=list)
    history: Dict[str, List[float]] = field(default_factory=dict)


def fit_lm(rng: jax.Array, orgs: List[LMOrganization], tokens: jnp.ndarray,
           labels: jnp.ndarray, rounds: int = 4, local_steps: int = 10,
           eta_method: str = "lbfgs", use_weights: bool = True,
           use_kernel: bool = False) -> GALLMResult:
    """Run GAL assistance rounds on an LM task (single host scale).

    tokens/labels: (B, S) int32. The overarching loss L1 is next-token xent;
    orgs fit logit-space residuals with ell_2 (paper Table 9 defaults).
    """
    b, s = labels.shape
    xent = CrossEntropyLoss()
    vocab = orgs[0].cfg.vocab
    y1 = jax.nn.one_hot(labels.reshape(-1), vocab)
    # F^0: log class prior over the batch (paper's E_N(y) init, link space)
    f0 = xent.init_prediction(y1)
    f = jnp.broadcast_to(f0, (b * s, vocab))
    result = GALLMResult(orgs=orgs, f0=f0)
    hist = result.history
    hist["train_xent"] = [float(xent(y1, f))]

    for t in range(rounds):
        k_round = jax.random.fold_in(rng, t)
        residual = compute_residual(
            labels, f.reshape(b, s, vocab), use_kernel=use_kernel)
        preds = []
        for org in orgs:
            fitted = org.fit_round(jax.random.fold_in(k_round, org.index),
                                   tokens, residual, local_steps=local_steps)
            preds.append(fitted.reshape(b * s, vocab))
        preds = jnp.stack(preds)                       # (M, B*S, V)
        if use_weights and len(orgs) > 1:
            w = fit_weights(jax.random.fold_in(k_round, 29),
                            residual.reshape(b * s, vocab), preds,
                            lambda r_, f_: jnp.mean(jnp.square(r_ - f_)),
                            epochs=60)
        else:
            w = uniform_weights(len(orgs))
        direction = jnp.einsum("m,mnk->nk", w, preds)
        eta = line_search(lambda e: xent(y1, f + e * direction),
                          method=eta_method, x0=1.0)
        f = f + eta * direction
        result.etas.append(float(eta))
        result.weights.append(w)
        hist["train_xent"].append(float(xent(y1, f)))
    return result
