"""GAL at LM scale: the paper's protocol with assigned-architecture orgs.

Alice holds next-token labels; each organization holds a private *view* of
the token stream (vertical split, e.g. vocab factorization or a modality) and
a private sequence model (any repro.configs architecture). Per round:

  1. Alice computes the pseudo-residual r = onehot(y) - softmax(F) in logit
     space with the fused Pallas kernel (repro.kernels.residual_xent).
  2. r is broadcast — dense (paper-faithful) or top-K compressed
     (beyond-paper transport; see train.steps.gal_residual_topk_loss).
  3. Each org runs `local_steps` SGD/AdamW steps of its architecture on the
     residual-fit objective.
  4. Alice fits assistance weights on the simplex and line-searches eta.
  5. F <- F + eta * sum_m w_m f_m.

Like ``repro.core.gal``, two engines execute this protocol:

  * a **fused scan path** when every org shares one architecture config:
    org params/optimizer states are stacked and the local fits vmapped, the
    T-round loop runs as one jitted ``lax.scan``, and the xent/eta/weight
    history is materialized device-side with a single host sync per
    ``fit_lm`` call;
  * the **Python reference path** for heterogeneous (model-autonomy)
    architectures — per-org dispatch, but history still syncs once at the
    end rather than per round.

This module stays deliberately *small*: it composes repro.core (weights,
line-search), repro.train.steps (losses, local-step scan) and repro.models
(architectures).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.losses import CrossEntropyLoss
from repro.core.plan import plan_lm_orgs
from repro.core.weights import fit_weights, uniform_weights
from repro.kernels.ops import residual_xent
from repro.models import transformer as tfm
from repro.optim.lbfgs import line_search
from repro.train.steps import make_train_step, run_local_steps


def compute_residual(labels: jnp.ndarray, ensemble_logits: jnp.ndarray,
                     use_kernel: bool = True) -> jnp.ndarray:
    """r = onehot(labels) - softmax(F): (B, S) x (B, S, V) -> (B, S, V)."""
    return residual_xent(ensemble_logits, labels, use_kernel=use_kernel)


def topk_compress(residual: jnp.ndarray, k: int):
    """Keep the k largest-|r| entries per token: (vals, idx)."""
    vals, idx = jax.lax.top_k(jnp.abs(residual), k)
    vals = jnp.take_along_axis(residual, idx, axis=-1)
    return vals, idx


@dataclass
class LMOrganization:
    """One org: private token view + private architecture."""
    index: int
    cfg: ModelConfig
    view_fn: Callable[[jnp.ndarray], jnp.ndarray]   # tokens -> private view
    params: Any = None
    opt_state: Any = None
    lr: Optional[float] = None
    _train_step: Any = None

    def init(self, rng: jax.Array, lr: float = 1e-3):
        self.params = tfm.init_params(rng, self.cfg)
        self.lr = lr
        self._train_step, opt = make_train_step(
            self.cfg, "gal_residual", lr=lr, weight_decay=0.0)
        self.opt_state = opt.init(self.params)

    def fit_round(self, rng: jax.Array, tokens: jnp.ndarray,
                  residual: jnp.ndarray, local_steps: int = 10) -> jnp.ndarray:
        """Fit the broadcast residual; return f_m(x_m) on the batch."""
        view = self.view_fn(tokens)
        batch = {"tokens": view, "residual": residual}
        self.params, self.opt_state, _ = run_local_steps(
            self._train_step, self.params, self.opt_state, batch, local_steps)
        logits, _ = tfm.apply(self.params, self.cfg, view)
        return logits.astype(jnp.float32)

    def predict(self, tokens: jnp.ndarray) -> jnp.ndarray:
        logits, _ = tfm.apply(self.params, self.cfg, self.view_fn(tokens))
        return logits.astype(jnp.float32)


@dataclass
class GALLMResult:
    orgs: List[LMOrganization]
    f0: jnp.ndarray
    etas: List[float] = field(default_factory=list)
    weights: List[jnp.ndarray] = field(default_factory=list)
    history: Dict[str, List[float]] = field(default_factory=dict)
    engine: str = "python"


def _l2(r, f):
    return jnp.mean(jnp.square(r - f))


def scan_compatible(orgs: List[LMOrganization]) -> bool:
    """The fused LM path needs one shared architecture config, one shared
    local learning rate (org 0's train step is vmapped over ALL org params,
    so differing optimizer settings would silently be overridden), and
    initialized params. View functions may differ — views are stacked,
    not the fns. Eligibility comes from the same execution planner as the
    tabular engines (``repro.core.plan.plan_lm_orgs``): compiled AND a
    single (cfg, lr) group."""
    plan = plan_lm_orgs(orgs)
    return plan.compiled and plan.n_groups == 1


def fit_lm(rng: jax.Array, orgs: List[LMOrganization], tokens: jnp.ndarray,
           labels: jnp.ndarray, rounds: int = 4, local_steps: int = 10,
           eta_method: str = "lbfgs", use_weights: bool = True,
           use_kernel: bool = False, engine: str = "auto") -> GALLMResult:
    """Run GAL assistance rounds on an LM task (single host scale).

    tokens/labels: (B, S) int32. The overarching loss L1 is next-token xent;
    orgs fit logit-space residuals with ell_2 (paper Table 9 defaults).
    ``engine``: auto | scan | python (see module docstring).
    """
    if engine not in ("auto", "scan", "python"):
        raise ValueError(f"unknown engine {engine!r}")
    plan = plan_lm_orgs(orgs)
    compatible = plan.compiled and plan.n_groups == 1
    if engine == "scan" and not compatible:
        raise ValueError(
            "engine='scan' needs one shared, initialized architecture "
            f"config across orgs: {plan.reason or plan.describe()}")
    if engine != "python" and compatible:
        return _fit_lm_scan(rng, orgs, tokens, labels, rounds, local_steps,
                            eta_method, use_weights, use_kernel)
    return _fit_lm_python(rng, orgs, tokens, labels, rounds, local_steps,
                          eta_method, use_weights, use_kernel)


def _fit_lm_scan(rng, orgs, tokens, labels, rounds, local_steps, eta_method,
                 use_weights, use_kernel) -> GALLMResult:
    """Fused path: org-stacked vmapped local fits inside one scanned round
    loop; exactly one host sync for the whole fit."""
    m = len(orgs)
    cfg = orgs[0].cfg
    b, s = labels.shape
    vocab = cfg.vocab
    xent = CrossEntropyLoss()
    y1 = jax.nn.one_hot(labels.reshape(-1), vocab)
    f0 = xent.init_prediction(y1)

    views = jnp.stack([org.view_fn(tokens) for org in orgs])     # (M, B, S)
    params0 = jax.tree_util.tree_map(
        lambda *ls: jnp.stack(ls), *[org.params for org in orgs])
    opts0 = jax.tree_util.tree_map(
        lambda *ls: jnp.stack(ls), *[org.opt_state for org in orgs])
    vstep = jax.vmap(orgs[0]._train_step,
                     in_axes=(0, 0, {"tokens": 0, "residual": None}))

    def run(key, y1_in, labels_in, views_in, params_in, opts_in):
        def round_step(carry, t):
            params, opts, f = carry
            k_round = jax.random.fold_in(key, t)
            residual = compute_residual(
                labels_in, f.reshape(b, s, vocab), use_kernel=use_kernel)
            params, opts, _ = run_local_steps(
                vstep, params, opts,
                {"tokens": views_in, "residual": residual}, local_steps)
            preds = jax.vmap(
                lambda p, v: tfm.apply(p, cfg, v)[0])(params, views_in)
            preds = preds.astype(jnp.float32).reshape(m, b * s, vocab)
            if use_weights and m > 1:
                w = fit_weights(jax.random.fold_in(k_round, 29),
                                residual.reshape(b * s, vocab), preds,
                                _l2, epochs=60)
            else:
                w = uniform_weights(m)
            direction = jnp.einsum("m,mnk->nk", w, preds)
            eta = line_search(lambda e: xent(y1_in, f + e * direction),
                              method=eta_method, x0=1.0)
            f = f + eta * direction
            return (params, opts, f), {"eta": eta, "w": w,
                                       "xent": xent(y1_in, f)}

        f_init = jnp.broadcast_to(xent.init_prediction(y1_in),
                                  (b * s, vocab))
        carry0 = (params_in, opts_in, f_init)
        (params, opts, _), outs = jax.lax.scan(
            round_step, carry0, jnp.arange(rounds))
        outs["xent0"] = xent(y1_in, f_init)
        return params, opts, outs

    params, opts, outs = jax.jit(run)(
        rng, y1, labels, views, params0, opts0)
    scalars = jax.device_get(outs)                # the ONE host sync

    for i, org in enumerate(orgs):                # write back evolved state
        org.params = jax.tree_util.tree_map(lambda l, i=i: l[i], params)
        org.opt_state = jax.tree_util.tree_map(lambda l, i=i: l[i], opts)

    result = GALLMResult(orgs=orgs, f0=f0, engine="scan")
    result.etas = [float(e) for e in scalars["eta"]]
    result.weights = [jnp.asarray(w) for w in scalars["w"]]
    result.history["train_xent"] = [float(scalars["xent0"])] + [
        float(v) for v in scalars["xent"]]
    return result


def _fit_lm_python(rng, orgs, tokens, labels, rounds, local_steps, eta_method,
                   use_weights, use_kernel) -> GALLMResult:
    """Reference path (heterogeneous architectures). History is accumulated
    device-side and fetched once at the end — no per-round float() syncs."""
    b, s = labels.shape
    xent = CrossEntropyLoss()
    vocab = orgs[0].cfg.vocab
    y1 = jax.nn.one_hot(labels.reshape(-1), vocab)
    # F^0: log class prior over the batch (paper's E_N(y) init, link space)
    f0 = xent.init_prediction(y1)
    f = jnp.broadcast_to(f0, (b * s, vocab))
    result = GALLMResult(orgs=orgs, f0=f0)
    etas_d, ws, xents = [], [], [xent(y1, f)]

    for t in range(rounds):
        k_round = jax.random.fold_in(rng, t)
        residual = compute_residual(
            labels, f.reshape(b, s, vocab), use_kernel=use_kernel)
        preds = []
        for org in orgs:
            fitted = org.fit_round(jax.random.fold_in(k_round, org.index),
                                   tokens, residual, local_steps=local_steps)
            preds.append(fitted.reshape(b * s, vocab))
        preds = jnp.stack(preds)                       # (M, B*S, V)
        if use_weights and len(orgs) > 1:
            w = fit_weights(jax.random.fold_in(k_round, 29),
                            residual.reshape(b * s, vocab), preds,
                            _l2, epochs=60)
        else:
            w = uniform_weights(len(orgs))
        direction = jnp.einsum("m,mnk->nk", w, preds)
        eta = line_search(lambda e: xent(y1, f + e * direction),
                          method=eta_method, x0=1.0)
        f = f + eta * direction
        etas_d.append(eta)
        ws.append(w)
        xents.append(xent(y1, f))

    etas_h, xents_h = jax.device_get((etas_d, xents))
    result.etas = [float(e) for e in etas_h]
    result.weights = ws
    result.history["train_xent"] = [float(v) for v in xents_h]
    return result
