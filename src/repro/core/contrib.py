"""Contributivity: counterfactual org valuation for a GAL collaboration.

How much did each organization's assistance actually buy? The GAL
protocol never shares data or models, so the only honest answer is
counterfactual: rerun the collaboration with org j (or a whole coalition)
absent and measure how much worse the final value gets. Dynamic
membership (``core.membership``) makes those counterfactuals exact AND
cheap:

* exact — a fit with org j masked out of every round is *bitwise* equal
  to fitting the reduced org set (the masked-softmax weight fit pins
  absent orgs to weight exactly 0.0, and XLA's reductions treat the
  resulting zero terms as inert; pinned by ``tests/test_membership.py``);
* cheap — the counterfactuals only need to diverge from round ``t0``
  onward, so one shared base fit to ``t0`` is saved as a resume carry and
  every coalition refit resumes from it, paying only ``rounds - t0``
  assistance rounds (the resumed rounds are draw-for-draw identical to a
  from-scratch masked fit; ``tests/test_membership.py`` pins that too).

Two estimators over the same coalition-value function
``v(S) = history[value][-1] of the fit where only S attends rounds t0..T``
with ``v(emptyset) = history[value][t0]`` (nobody assists past the base):

* ``leave_one_out`` — ``score_j = v(all - {j}) - v(all)``: the value
  increase when org j alone walks away. M counterfactual refits.
* ``truncated_shapley`` — TMC-Shapley (Ghorbani & Zou, 2019): the
  permutation-averaged marginal ``v(S) - v(S + {j})``, sampled over
  permutations (exhaustive when M! fits the budget, where the estimate is
  the exact Shapley value and satisfies efficiency:
  ``sum(scores) == v(emptyset) - v(all)``), with an optional truncation
  tolerance that stops a permutation walk once the running value is
  within ``truncation_tol`` of the full-coalition value. Coalition values
  are cached by frozenset, so the refit count is the number of DISTINCT
  coalitions visited, not permutations x M.

Scores measure the DECREASE in ``value`` attributable to the org:
positive = the org lowers the recorded column (good when ``value`` is a
loss; flip the reading for higher-is-better metric columns). Both
estimators ledger their report into ``full.history["contributions"]`` —
a dict column the artifact resume machinery deliberately ignores — and
``launch.serve --contributions`` prints it as a per-org table.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Dict, Optional

import numpy as np


def _coalition_values(rng, orgs, y, loss, config, t0, value, eval_sets,
                      full=None):
    """Build the cached coalition-value closure shared by both estimators.

    Returns ``(full, v, v_full, v_empty, counter)`` where ``v(S)`` maps an
    iterable of org positions to the final ``value`` of the counterfactual
    fit in which only ``S`` attends rounds ``t0..T``, and ``counter`` is a
    single-element list tracking how many refits actually ran."""
    from repro.core import gal as gal_mod

    m = len(orgs)
    rounds = config.rounds
    if not 0 <= t0 < rounds:
        raise ValueError(f"t0 must be in [0, rounds)=[0, {rounds}), got {t0}")
    if full is None:
        full = gal_mod.fit(rng, orgs, y, loss, config, eval_sets=eval_sets)
    if value not in full.history:
        raise ValueError(
            f"value column {value!r} not in the fit history; available: "
            f"{sorted(full.history)}")
    v_full = float(full.history[value][-1])
    # the shared base: everything before t0 is common to every coalition,
    # so fit it once and resume each counterfactual from its carry
    base = None
    if t0 > 0:
        base = gal_mod.fit(rng, orgs, y, loss,
                           dataclasses.replace(config, rounds=t0),
                           eval_sets=eval_sets)
    v_empty = float(full.history[value][t0])
    cache: Dict[frozenset, float] = {frozenset(range(m)): v_full,
                                     frozenset(): v_empty}
    counter = [0]

    def v(coalition) -> float:
        fs = frozenset(int(j) for j in coalition)
        if not fs <= set(range(m)):
            raise ValueError(f"coalition {sorted(fs)} has org positions "
                             f"outside range({m})")
        if fs in cache:
            return cache[fs]
        sched = np.ones((rounds, m), bool)
        sched[t0:, :] = False
        sched[t0:, sorted(fs)] = True
        res = gal_mod.fit(rng, orgs, y, loss, config, eval_sets=eval_sets,
                          membership=sched, resume_from=base)
        counter[0] += 1
        val = float(res.history[value][-1])
        cache[fs] = val
        return val

    return full, v, v_full, v_empty, counter


def leave_one_out(rng, orgs, y, loss, config, *, t0: int = 0,
                  value: str = "train_loss", eval_sets=None,
                  full=None) -> Dict[str, Any]:
    """Leave-one-out contributivity: ``score_j = v(all - {j}) - v(all)``.

    ``full`` optionally passes an already-completed fit of the SAME
    (rng, orgs, config) so it is not refit. The report is returned AND
    ledgered into ``full.history["contributions"]``."""
    m = len(orgs)
    full, v, v_full, v_empty, counter = _coalition_values(
        rng, orgs, y, loss, config, t0, value, eval_sets, full)
    everyone = set(range(m))
    scores = [v(everyone - {j}) - v_full for j in range(m)]
    report = {
        "method": "loo", "value": value, "t0": int(t0),
        "v_full": v_full, "v_empty": v_empty,
        "scores": scores, "org_ids": [int(o.index) for o in orgs],
        "refits": counter[0],
    }
    full.history["contributions"] = report
    return report


def truncated_shapley(rng, orgs, y, loss, config, *, t0: int = 0,
                      value: str = "train_loss", eval_sets=None,
                      n_permutations: Optional[int] = None,
                      truncation_tol: float = 0.0, perm_seed: int = 0,
                      full=None) -> Dict[str, Any]:
    """Truncated-Monte-Carlo Shapley over the coalition-value function.

    ``n_permutations`` defaults to exhaustive (all M!) for M <= 4 and
    ``4 * M`` sampled permutations otherwise; passing ``>= M!`` always
    goes exhaustive, making the estimate the exact Shapley value —
    invariant under org relabeling and efficient
    (``sum(scores) == v_empty - v_full``). ``truncation_tol`` stops a
    permutation walk early once ``|v(S) - v_full| <= truncation_tol``
    (the remaining orgs in that permutation get a zero marginal)."""
    m = len(orgs)
    full, v, v_full, v_empty, counter = _coalition_values(
        rng, orgs, y, loss, config, t0, value, eval_sets, full)
    total_perms = math.factorial(m)
    if n_permutations is None:
        n_permutations = total_perms if total_perms <= 24 else 4 * m
    if n_permutations < 1:
        raise ValueError(f"n_permutations must be >= 1, got {n_permutations}")
    exhaustive = n_permutations >= total_perms
    if exhaustive:
        perms = list(itertools.permutations(range(m)))
    else:
        prng = np.random.default_rng(perm_seed)
        perms = [tuple(int(j) for j in prng.permutation(m))
                 for _ in range(n_permutations)]

    totals = np.zeros(m, np.float64)
    truncated_walks = 0
    for perm in perms:
        coalition: list = []
        prev = v_empty
        for pos, j in enumerate(perm):
            if truncation_tol > 0.0 and abs(prev - v_full) <= truncation_tol:
                truncated_walks += 1
                break                 # remaining marginals treated as zero
            coalition.append(j)
            cur = v(coalition)
            totals[j] += prev - cur
            prev = cur
    scores = (totals / len(perms)).tolist()
    report = {
        "method": "shapley", "value": value, "t0": int(t0),
        "v_full": v_full, "v_empty": v_empty,
        "scores": scores, "org_ids": [int(o.index) for o in orgs],
        "n_permutations": len(perms), "exhaustive": exhaustive,
        "truncation_tol": float(truncation_tol),
        "truncated_walks": truncated_walks,
        "refits": counter[0],
    }
    full.history["contributions"] = report
    return report
