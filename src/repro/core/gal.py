"""The GAL round engine (paper Algorithm 1), from Alice's perspective.

Per assistance round t:
  1. r^t   = -dL1(y, F^{t-1})/dF          (pseudo-residual, Alice)
  2. broadcast r^t (optionally privatized: DP/IP)          -> all orgs
  3. f_m^t = argmin_{f in F_m} E_N ell_m(r^t, f(x_m))       (orgs, parallel)
  4. w-hat = argmin_{w in simplex} E_N ell_1(r^t, sum w_m f_m^t)   (Alice)
  5. eta-hat = argmin_eta E_N L1(y, F^{t-1} + eta sum w_m f_m^t)   (Alice, L-BFGS)
  6. F^t = F^{t-1} + eta-hat * sum_m w-hat_m f_m^t

Prediction stage: F^T(x*) = F^0 + sum_t eta^t sum_m w_m^t f_m^t(x_m*).

Engine selection is driven by the org execution planner
(``repro.core.plan.plan_orgs``), which partitions the organizations into
homogeneous groups (model signature, local ell_q, noise sigma, slice rank)
or names the reason the compiled engines cannot run. Four executions of the
same algorithm live here:

  * the **org-sharded multi-device path** (``repro.core.engine.fit_shard``):
    single-group noiseless plans with the org axis mapped onto a real
    device mesh — one organization per device along an "org" axis; residual
    broadcast / fitted-value gather / weighted direction run as real
    collectives (``GALConfig.engine="shard"`` forces it);
  * the **grouped fused engine** (``repro.core.engine.fit_grouped``): ANY
    plan the planner compiles — heterogeneous model autonomy (the paper's
    GB–SVM mix), per-org local losses (ell_q or any traceable custom
    callable via the autodiff-residual path), noisy orgs, and Deep Model
    Sharing (shared extractor in the scan carry, per-round heads stacked
    on a (T, ...) axis) — one vmap per group inside the same scanned round
    step, group fitted values concatenated in org order before the weight
    fit, single host sync per ``fit``; on a matching device count the
    group stacks shard over an "org" mesh (``GALConfig.engine="grouped"``
    forces it);
  * the **scan fast path** (``repro.core.engine.fit_scan``): the legacy
    single-group veneer over the grouped engine for homogeneous orgs
    (``GALConfig.engine="scan"`` forces it);
  * the **Python reference path**: per-org dispatch in interpreter order —
    now a pure TEST ORACLE (``tests/test_conformance.py``); the remaining
    TRUE fallbacks are genuinely non-array inputs, non-scan-safe models
    and non-traceable local losses (``GALConfig.engine="python"`` forces
    it).

Every engine records the per-round communication and model-memory ledgers
(``history["comm_broadcast_bytes"/"comm_gather_bytes"/"model_memories"]``)
under the paper's Table-14 convention via ``repro.core.protocol_sim`` — the
shard engine's numbers come from its real collective operand shapes, the
other engines simulate the identical wire protocol. Eval metrics are
device-side on every engine (``metrics=...`` resolved from
``repro.metrics.METRICS``), evaluated inside the round loop.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as engine_mod
from repro.core.losses import Loss, lq_loss
from repro.core.organizations import Organization
from repro.core.plan import (ExecutionPlan, dms_interface_reason,
                             plan_orgs)
from repro.core.privacy import apply_privacy
from repro.core.protocol_sim import gal_model_memories, gal_round_bytes
from repro.core.weights import fit_weights, uniform_weights
from repro.launch.mesh import org_mesh_eligible
from repro.metrics.metrics import METRICS, get_metric
from repro.optim.lbfgs import line_search

_COMPILED_ENGINES = ("scan", "shard", "grouped")


def _resolve_metrics(metric_fn, metrics, eval_sets):
    """Normalize the metric arguments into one ``{column: fn}`` map.

    ``metrics`` entries are registry names (``repro.metrics.METRICS``) or
    pure-jnp callables (column = ``__name__``); the legacy single
    ``metric_fn`` keeps its historical ``"<eval>_metric"`` column. Every
    metric is validated up front with ``jax.eval_shape`` — ALL engines now
    evaluate metrics device-side inside the round loop (the host-side
    metric escape hatch is retired), so a non-traceable callable is an
    error naming the registry, not a silent Python fallback."""
    mmap: Dict[str, Callable] = {}
    if metric_fn is not None:
        mmap["metric"] = metric_fn
    for entry in (metrics or ()):
        name = entry if isinstance(entry, str) else \
            getattr(entry, "__name__", f"metric{len(mmap)}")
        # each metric owns one "<eval>_<name>" column: a duplicate would
        # silently clobber it, and "loss" would collide with the per-round
        # loss curve the engines already record
        if name == "loss":
            raise ValueError(
                "metric name 'loss' collides with the engines' per-round "
                "'<eval>_loss' column; rename the callable")
        if name in mmap:
            raise ValueError(
                f"duplicate metric name {name!r}: each metric needs a "
                f"distinct history column (rename the callable or drop "
                f"the duplicate)")
        mmap[name] = get_metric(entry) if isinstance(entry, str) else entry
    if not mmap:
        return None
    if eval_sets:
        for mname, fn in mmap.items():
            if not engine_mod.metric_traceable(fn, eval_sets):
                raise ValueError(
                    f"metric {mname!r} is not jax-traceable (failed "
                    f"jax.eval_shape over the eval shapes): every engine "
                    f"evaluates metrics device-side inside the round loop "
                    f"now — use a registry metric "
                    f"(repro.metrics.METRICS: {METRICS.names()}) or a "
                    f"pure-jnp callable")
    return mmap


@dataclass(frozen=True)
class GALConfig:
    rounds: int = 10
    # assisted learning rate (paper: L-BFGS line search; eta=1 const ablation)
    eta_method: str = "lbfgs"          # lbfgs | golden | constant
    eta0: float = 1.0
    eta_stop_threshold: float = 0.0    # stop assistance when |eta| drops below
    # gradient assistance weights (paper: softmax+Adam; uniform ablation)
    use_weights: bool = True
    weight_epochs: int = 100
    weight_lr: float = 0.1
    weight_decay: float = 5e-4
    # Alice's regression loss ell_1 used in the weight objective
    alice_q: float = 2.0
    # privacy on the broadcast residual (paper Sec 4.5)
    privacy: Optional[str] = None      # None | dp | ip
    privacy_alpha: float = 1.0
    privacy_intervals: int = 1
    # wire dtype of the step-2 residual broadcast: "bf16" casts the
    # privatized residual to bfloat16 BEFORE it leaves Alice (halving the
    # ledgered comm_broadcast_bytes exactly) and upcasts after; every
    # engine applies the identical cast, so they stay draw-for-draw equal
    # under compression too. "float32" is the uncompressed protocol.
    residual_dtype: str = "float32"    # float32 | bf16
    # org-sharded engine only: shard each org's N training rows across a
    # second "data" mesh axis (device_count must factor as org-axis size x
    # data_shards; see launch.mesh.org_mesh_eligible). The per-round local
    # fits, weight fit, and eta line search reduce across it.
    data_shards: int = 1
    # dynamic-membership fault injection (core/membership.py): each org
    # independently skips each round with probability straggler_sim, from a
    # schedule seeded by straggler_seed (deterministic per config; rounds
    # are repaired so at least one org always attends). Composes (AND)
    # with an explicit fit(membership=...) schedule.
    straggler_sim: Optional[float] = None
    straggler_seed: int = 0
    # engine selection: "auto" asks the planner (repro.core.plan) and picks
    # the most capable engine that applies — org-sharded collectives for a
    # single noiseless group on an org mesh, the scan fast path for a
    # single noiseless group on one host, the grouped fused engine for any
    # other compilable plan (heterogeneous models, per-org/custom losses,
    # noisy orgs, Deep Model Sharing), else the Python reference loop.
    # "python" forces the reference loop; "scan"/"shard"/"grouped" force a
    # compiled engine, raising with the planner's ineligibility reason when
    # it cannot run. NOTE metrics/metric_fn are traced device-side on EVERY
    # engine — they must be jax-traceable (repro.metrics.METRICS entries
    # are).
    engine: str = "auto"               # auto | scan | shard | grouped | python


@dataclass
class GALResult:
    orgs: List[Organization]
    loss: Loss
    f0: jnp.ndarray                    # (1, K)
    etas: List[float] = field(default_factory=list)
    weights: List[jnp.ndarray] = field(default_factory=list)
    history: Dict[str, List[float]] = field(default_factory=dict)
    # compiled-engine extras. Single-group results keep the legacy fields:
    # per-round params as ONE stacked pytree with leaves (T, M, ...), the
    # shared model that applies them, and the padded input geometry needed
    # to stack prediction-stage slices.
    stacked_params: Any = None
    model: Any = None
    org_dims: Optional[List[int]] = None
    pad_to: Optional[int] = None
    # planner-grouped results (any compiled engine): the ExecutionPlan that
    # ran, per-GROUP stacked params (list of pytrees, leaves (T, M_g, ...))
    # and per-group stacking geometry; prediction stays one vmap+einsum per
    # group (engine.grouped_predict).
    plan: Optional[ExecutionPlan] = None
    group_params: Optional[List[Any]] = None
    group_dims: Optional[List[List[int]]] = None
    group_pads: Optional[List[Optional[int]]] = None
    mesh_devices: int = 0              # devices the group stacks sharded over
    engine: str = "python"
    # the config this result was fit with (stored in the artifact manifest
    # and compat-checked on resume)
    config: Optional["GALConfig"] = None
    # compiled engines only: the final round-scan carry — ensemble state f,
    # per-eval-set carries, post-scan RNG key, early-stop flag, DMS
    # extractor/head/residual buffers, and the resume cursor t_next. This
    # is what checkpoint.save_artifact persists and
    # fit(..., resume_from=...) restores; python-engine results keep None
    # (their state lives in the Organization objects and cannot resume).
    resume_state: Optional[Dict[str, Any]] = None
    # the executed membership ledger: one row of per-org attendance bools
    # per executed round (org order), or None when every org attended
    # every round and no schedule was requested. Persisted in the
    # gal-artifact/v1 manifest; a grown resume pads the historical rows
    # with False for the joining orgs.
    membership: Optional[List[List[bool]]] = None

    @property
    def rounds(self) -> int:
        return len(self.etas)

    def predict(self, xs: Sequence[jnp.ndarray], rounds: Optional[int] = None
                ) -> jnp.ndarray:
        """Prediction stage: assemble org outputs for new data xs[m].

        Fast-path results evaluate the whole (rounds x orgs) ensemble with a
        nested vmap + one einsum; reference results loop per (round, org).
        """
        t_max = self.rounds if rounds is None else min(rounds, self.rounds)
        if self.group_params is not None and self.plan is not None:
            return engine_mod.grouped_predict(
                self.plan.groups, self.group_params, self.group_dims,
                self.group_pads, self.etas, self.weights, self.f0, xs,
                t_max,
            )
        return self.predict_legacy(xs, rounds)

    def predict_legacy(self, xs: Sequence[jnp.ndarray],
                       rounds: Optional[int] = None) -> jnp.ndarray:
        """Per-(round, org) Python assembly of the prediction stage — the
        reference the stacked path is measured against (benchmarks, serving).
        Needs per-org round params: call ``unpack_to_orgs()`` first on
        fast-path results, and pad xs to ``pad_to`` columns there.

        Reads LIVE Organization state: a later ``gal.fit``/``al.fit`` on
        the same org objects resets it (see
        ``Organization.reset_round_state``) and invalidates this path for
        results of earlier fits — refit fresh orgs to keep old results."""
        if not self.orgs:
            raise ValueError(
                "this result has no Organizations attached (loaded from an "
                "artifact): predict() serves directly from the stacked "
                "group params; the legacy per-(round, org) path needs live "
                "orgs")
        t_max = self.rounds if rounds is None else min(rounds, self.rounds)
        n = xs[0].shape[0]
        f = jnp.broadcast_to(self.f0, (n, self.f0.shape[-1]))
        for t in range(t_max):
            preds = jnp.stack([
                org.predict_round(t, xs[m]) for m, org in enumerate(self.orgs)
            ])
            f = f + self.etas[t] * jnp.einsum("m,mnk->nk", self.weights[t], preds)
        return f

    def unpack_to_orgs(self) -> None:
        """Copy fast-path per-round params back into the Organization objects
        so legacy per-(round, org) flows (``predict_round``) work. The params
        were fit on slices zero-padded to each group's pad width (``pad_to``
        for single-group results, ``group_pads[g]`` otherwise) — pad inputs
        with ``repro.data.partition.pad_and_stack`` before applying them.
        DMS groups restore the shared extractor and the per-round head list
        from the stacked ``(T, ...)`` head buffer."""
        if not self.orgs:
            raise ValueError(
                "this result has no Organizations attached (loaded from an "
                "artifact): there is nothing to unpack into — serve through "
                "predict(), or resume the fit with the original org data")
        if self.group_params is not None and self.plan is not None:
            for gi, g in enumerate(self.plan.groups):
                for j, i in enumerate(g.indices):
                    if g.dms:
                        gp = self.group_params[gi]
                        self.orgs[i]._dms_extractor = \
                            jax.tree_util.tree_map(
                                lambda l, j=j: l[j], gp["extractor"])
                        self.orgs[i]._dms_heads = [
                            jax.tree_util.tree_map(
                                lambda l, t=t, j=j: l[j, t], gp["heads"])
                            for t in range(self.rounds)
                        ]
                        continue
                    self.orgs[i]._round_params = [
                        jax.tree_util.tree_map(
                            lambda l, t=t, j=j: l[t, j],
                            self.group_params[gi])
                        for t in range(self.rounds)
                    ]
            return
        if self.stacked_params is None:
            return
        for i, org in enumerate(self.orgs):
            org._round_params = [
                jax.tree_util.tree_map(
                    lambda l, t=t, i=i: l[t, i], self.stacked_params)
                for t in range(self.rounds)
            ]


def fit(rng: jax.Array, orgs: List[Organization], y: jnp.ndarray, loss: Loss,
        config: GALConfig = GALConfig(),
        eval_sets: Optional[Dict[str, tuple]] = None,
        metric_fn: Optional[Callable] = None,
        metrics: Optional[Sequence] = None,
        resume_from: Any = None,
        membership: Any = None) -> GALResult:
    """Run T assistance rounds. ``eval_sets`` maps name -> (xs_list, y) and is
    evaluated with the *prediction-stage* mechanics each round (paper's
    validation protocol), producing the per-round curves of Fig. 4.

    ``metrics`` names device-side eval metrics — registry names from
    ``repro.metrics.METRICS`` (``"mad"``, ``"accuracy"``, ``"auroc"``) or
    pure-jnp callables — each recorded per round as
    ``history["<eval>_<metric>"]`` inside the engines' single host sync.
    The legacy single ``metric_fn`` still fills ``history["<eval>_metric"]``
    but is now traced device-side on EVERY engine (including the Python
    reference); non-traceable callables raise up front.

    ``resume_from`` extends a previously fitted collaboration instead of
    starting one: pass a compiled-engine ``GALResult`` (in-memory) or the
    path of a ``checkpoint.save_artifact`` directory. The engines restore
    the round-scan carry — ensemble state, per-eval carries, RNG chain,
    early-stop flag, DMS buffers — and run only rounds ``t0..T``
    (``t0`` = the artifact's completed rounds, ``T = config.rounds``),
    appending etas/weights/history columns so the resumed result is
    draw-for-draw identical to an uninterrupted ``T``-round fit. The org
    set must plan into the identical group partition (same models, losses,
    sigmas, slice widths) — or into a *compatible growth* of it (mid-fit
    join): the original orgs unchanged in their original positions plus
    new orgs appended after them, each joining an existing non-DMS group
    (same model/loss/sigma, slice width within the group's fitted pad) or
    forming a new non-DMS group. Joining orgs enter at round ``t0`` with a
    zeroed weight history — the stitched result's weights, group params
    and membership ledger carry exact zeros for them over the already-
    completed rounds. The config must match except ``rounds`` /
    ``engine``, and the eval-set names must match the saved carries; any
    divergence raises with the specific mismatch.

    ``membership`` is an optional (rounds, M) boolean attendance schedule
    (see ``repro.core.membership``): orgs absent from round t are masked
    out of that round's weight fit (weight exactly 0.0), contribute
    nothing to the direction, and drop out of the round's communication /
    model-memory ledgers. ``GALConfig.straggler_sim`` composes a seeded
    random dropout schedule on top (logical AND). On a resume, schedule
    rows before ``t0`` are overridden by the collaboration's recorded
    history (the artifact's membership ledger; joining orgs absent).

    Engine dispatch is planner-driven: ``repro.core.plan.plan_orgs``
    partitions the orgs into homogeneous groups or names the reason the
    compiled engines cannot run; forcing a compiled engine on an
    uncompilable set raises that reason verbatim."""
    if config.engine not in ("auto", "python") + _COMPILED_ENGINES:
        raise ValueError(f"unknown engine {config.engine!r}")
    if config.residual_dtype not in ("float32", "fp32", "bf16", "bfloat16"):
        raise ValueError(
            f"unknown residual_dtype {config.residual_dtype!r}: "
            "expected 'float32' or 'bf16'")
    if config.data_shards < 1:
        raise ValueError(f"data_shards must be >= 1, got "
                         f"{config.data_shards}")
    if config.data_shards > 1 and config.engine not in ("auto", "shard"):
        raise ValueError(
            f"data_shards={config.data_shards} needs the org-sharded "
            f"engine (its 'data' mesh axis); engine={config.engine!r} "
            "cannot honor it — use engine='shard' or 'auto'")
    for org in orgs:
        org.reset_round_state()  # a refit must not read stale round params
    metric_map = _resolve_metrics(metric_fn, metrics, eval_sets)
    plan = plan_orgs(orgs, eval_sets,
                     probe_shape=(int(y.shape[0]), int(y.shape[-1])))
    if config.data_shards > 1 and not (
            plan.compiled and plan.homogeneous
            and org_mesh_eligible(len(orgs), config.data_shards)):
        raise ValueError(
            f"data_shards={config.data_shards} needs a homogeneous org set "
            f"on an (org x data) mesh: {len(orgs)} orgs over "
            f"{jax.device_count()} devices / {config.data_shards} data "
            f"shard(s) is not eligible "
            f"({plan.reason or 'see launch.mesh.org_mesh_eligible'})")
    from repro.core.membership import resolve_membership
    sched = resolve_membership(membership, config.straggler_sim,
                               config.straggler_seed, config.rounds,
                               len(orgs))

    resume_art = resume_eng = growth = None
    if resume_from is not None:
        if isinstance(resume_from, (str, Path)):
            from repro.checkpoint.checkpoint import load_artifact
            # custom (non-registry) models/losses are stored by name only;
            # the org set being resumed holds the live objects, so resolve
            # the artifact's names against them (the artifact stores names,
            # not code — supplying the same-named implementation is the
            # caller's side of that contract, as with load_artifact)
            models_map: Dict[str, Any] = {}
            losses_map: Dict[str, Any] = {}
            for o in orgs:
                models_map.setdefault(type(o.model).__name__, o.model)
                if o.local_loss is not None:
                    # same name fallback chain as checkpoint.loss_spec, so
                    # partials/callable instances resolve too
                    losses_map.setdefault(
                        getattr(o.local_loss, "__name__",
                                type(o.local_loss).__name__), o.local_loss)
            losses_map.setdefault(
                getattr(loss, "__name__", type(loss).__name__), loss)
            resume_art = load_artifact(resume_from, losses=losses_map,
                                       models=models_map)
        else:
            resume_art = resume_from
        if config.engine == "python":
            raise ValueError(
                "resume_from needs a compiled engine (the python reference "
                "loop holds its state in live Organization objects and "
                "cannot restore an artifact carry); use engine='auto'")
        if not plan.compiled:
            raise ValueError(
                f"resume_from needs a compilable organization set: "
                f"{plan.reason}")
        resume_eng, growth = _prepare_resume(resume_art, orgs, plan, y,
                                             loss, config, eval_sets,
                                             metric_map)
        if growth is not None and config.straggler_sim:
            raise ValueError(
                "straggler_sim cannot span a mid-fit join: the seeded "
                "schedule draws over (rounds, M) and a grown M would "
                "retroactively change the already-completed rounds' "
                "draws — pass an explicit membership schedule instead")
        sched = _resume_schedule(resume_art, resume_eng, growth, sched,
                                 config, len(orgs))

    if not plan.compiled:
        if config.engine in _COMPILED_ENGINES:
            # the ONE ineligibility path for every compiled engine: the
            # planner's human-readable reason, verbatim
            raise ValueError(
                f"engine={config.engine!r} cannot compile these "
                f"organizations: {plan.reason}")
        # interface check only, NOT scan_safe: a duck-typed model with the
        # full extractor/head surface still runs the reference DMS loop.
        # When even that surface is missing, the python engine cannot run
        # it either — surface a clear error instead of an AttributeError
        # three steps into round 0.
        for o in orgs:
            why = (dms_interface_reason(o)
                   if getattr(o, "dms", False) else None)
            if why:
                raise ValueError(
                    f"cannot run these organizations on ANY engine: {why}")
        return _fit_python(rng, orgs, y, loss, config, eval_sets,
                           metric_map, membership=sched)
    if config.engine == "python":
        return _fit_python(rng, orgs, y, loss, config, eval_sets,
                           metric_map, membership=sched)

    result = _dispatch_compiled(rng, orgs, y, loss, config, eval_sets,
                                metric_map, plan, resume_eng, sched)
    if resume_art is not None:
        result = _stitch_resume(resume_art, result, plan, growth=growth)
    return result


def _resume_schedule(art: GALResult, resume_eng: Dict[str, Any], growth,
                     sched, config: GALConfig, m: int):
    """Assemble the full-rounds engine schedule for a resumed fit: rows
    before ``t_next`` are the collaboration's recorded history — the
    artifact's membership ledger over the original orgs, padded with False
    for orgs joining now — and rows from ``t_next`` on come from the
    caller's resolved schedule (all live when none was given). Historical
    rows drive the DMS dead-slot masks and the stitched ledger; they are
    never re-executed. Returns None when no membership story exists at
    all (no schedule, no artifact ledger, no join), which keeps the
    pre-membership engine path bit-for-bit."""
    art_rows = art.membership
    if sched is None and art_rows is None and growth is None:
        return None
    t0 = int(resume_eng["t_next"])
    m_old = growth["m_old"] if growth is not None else m
    hist = (np.ones((t0, m_old), bool) if art_rows is None
            else np.asarray(art_rows, bool))
    if hist.shape != (t0, m_old):
        raise ValueError(
            f"artifact membership ledger shape {hist.shape} does not "
            f"match its {t0} completed rounds over {m_old} orgs")
    full = np.zeros((t0, m), bool)
    full[:, :m_old] = hist
    exec_rows = (np.ones((config.rounds - t0, m), bool) if sched is None
                 else np.asarray(sched, bool)[t0:])
    return np.vstack([full, exec_rows])


def _dispatch_compiled(rng, orgs, y, loss, config, eval_sets, metric_map,
                       plan, resume, membership=None) -> GALResult:
    if config.engine == "scan":
        if not plan.homogeneous:
            raise ValueError(
                "engine='scan' runs ONE noiseless homogeneous group; the "
                f"planner found {plan.describe()} — use engine='grouped' "
                "(or 'auto') to fuse heterogeneous/noisy/DMS organizations")
        return _fit_fast(engine_mod.fit_scan, "scan", plan,
                         rng, orgs, y, loss, config, eval_sets, metric_map,
                         resume=resume, membership=membership)
    if config.engine == "shard":
        if plan.homogeneous:
            # fit_shard itself raises the org-mesh "must divide" error
            return _fit_fast(engine_mod.fit_shard, "shard", plan,
                             rng, orgs, y, loss, config, eval_sets,
                             metric_map, resume=resume,
                             membership=membership)
        return _fit_fast(engine_mod.fit_grouped, "grouped", plan,
                         rng, orgs, y, loss, config, eval_sets, metric_map,
                         require_mesh=True, resume=resume,
                         membership=membership)
    if config.engine == "grouped":
        return _fit_fast(engine_mod.fit_grouped, "grouped", plan,
                         rng, orgs, y, loss, config, eval_sets, metric_map,
                         resume=resume, membership=membership)
    # auto: most capable engine that applies
    if plan.homogeneous and org_mesh_eligible(len(orgs),
                                              config.data_shards):
        return _fit_fast(engine_mod.fit_shard, "shard", plan,
                         rng, orgs, y, loss, config, eval_sets, metric_map,
                         resume=resume, membership=membership)
    if plan.homogeneous:
        return _fit_fast(engine_mod.fit_scan, "scan", plan,
                         rng, orgs, y, loss, config, eval_sets, metric_map,
                         resume=resume, membership=membership)
    return _fit_fast(engine_mod.fit_grouped, "grouped", plan,
                     rng, orgs, y, loss, config, eval_sets, metric_map,
                     resume=resume, membership=membership)


def _fit_fast(engine_fn, name, plan, rng, orgs, y, loss, config, eval_sets,
              metrics, require_mesh: bool = False,
              resume: Optional[Dict[str, Any]] = None,
              membership=None) -> GALResult:
    if engine_fn is engine_mod.fit_shard:
        out = engine_fn(rng, orgs, y, loss, config, eval_sets, metrics,
                        resume=resume, membership=membership)
    else:
        if require_mesh:
            from repro.launch.mesh import grouped_mesh_eligible
            if plan.has_dms:
                raise ValueError(
                    "engine='shard' cannot org-shard a Deep Model Sharing "
                    "plan (its extractor/head carry is single-host); use "
                    "engine='grouped' (or 'auto')")
            if not grouped_mesh_eligible([g.size for g in plan.groups]):
                raise ValueError(
                    f"engine='shard' on a {plan.n_groups}-group plan needs "
                    f"the device count ({len(jax.devices())}) to divide "
                    f"every group size {[g.size for g in plan.groups]} on "
                    "a multi-device host; use engine='grouped' for the "
                    "single-host fused path")
        out = engine_fn(rng, orgs, y, loss, config, eval_sets, metrics,
                        plan=plan, resume=resume, membership=membership)
    return _fast_result(orgs, y, loss, out, name, plan, config)


def _fast_result(orgs, y, loss, out, engine: str, plan: ExecutionPlan,
                 config: Optional[GALConfig] = None) -> GALResult:
    single = plan.n_groups == 1 and not plan.has_dms
    group_params = out.get("group_params")
    if group_params is None:            # fit_shard: legacy single-stack dict
        group_params = [out["params"]]
        group_dims = [out["dims"]]
        group_pads = [out["pad_to"]]
    else:
        group_dims = out["group_dims"]
        group_pads = out["group_pads"]
    return GALResult(
        orgs=orgs, loss=loss, f0=loss.init_prediction(y),
        etas=out["etas"], weights=out["weights"], history=out["history"],
        stacked_params=out.get("params") if single else None,
        model=plan.groups[0].model if single else None,
        org_dims=group_dims[0] if single else None,
        pad_to=group_pads[0] if single else None,
        plan=plan, group_params=group_params, group_dims=group_dims,
        group_pads=group_pads, mesh_devices=out.get("mesh_devices", 0),
        engine=engine, config=config, resume_state=out.get("resume"),
        membership=out.get("membership"),
    )


# history columns with NO round-0 init row (appended per executed round
# only): the stitcher concatenates them verbatim, everything else drops
# the resumed segment's restored-carry "init" entry first
_LEDGER_COLS = ("comm_broadcast_bytes", "comm_gather_bytes",
                "model_memories")


def _prepare_resume(art: GALResult, orgs, plan: ExecutionPlan, y, loss,
                    config: GALConfig, eval_sets,
                    metric_map: Optional[Dict[str, Callable]] = None
                    ) -> tuple:
    """Validate a resume request against the artifact and build the engine
    resume dict. Every check raises with the specific mismatch — a resumed
    carry on the wrong org set / config / data would produce silently
    wrong rounds, which is strictly worse than an error.

    Returns ``(resume_dict, growth)``: ``growth`` is None for an identical
    org set, or — for a *compatible growth* (mid-fit join, see
    ``plan.plan_growth_mismatch``) — a dict with the artifact geometry the
    stitcher needs (``m_old``, per-old-group sizes) to zero-pad the
    joining orgs' completed-round history."""
    import dataclasses as _dc

    from repro.checkpoint.checkpoint import loss_spec, model_spec
    from repro.core.plan import (plan_growth_mismatch, plan_mismatch,
                                 plan_to_manifest)
    from repro.data.partition import group_widths

    rs = art.resume_state
    if rs is None:
        raise ValueError(
            "this result/artifact has no resume state: python-engine fits "
            "hold their rounds in live Organization objects and cannot "
            "resume — refit on a compiled engine and save that")
    manifest = plan_to_manifest(art.plan, model_spec, loss_spec)
    growth = None
    why = plan_mismatch(plan, manifest, model_spec, loss_spec)
    if why is not None:
        gwhy = plan_growth_mismatch(plan, manifest, model_spec, loss_spec)
        if gwhy is not None:
            raise ValueError(
                f"resume_from organization set does not match the "
                f"artifact's execution plan ({why}) and is not a "
                f"compatible growth of it ({gwhy})")
        old_sizes = [len(g["org_ids"]) for g in manifest["groups"]]
        growth = {"m_old": sum(old_sizes), "old_sizes": old_sizes,
                  "n_old_groups": len(old_sizes)}
    dims_now = group_widths([o.x_train for o in orgs],
                            [g.indices for g in plan.groups])
    dims_art = [[int(d) for d in gd] for gd in art.group_dims]
    if growth is None:
        if dims_now != dims_art:
            raise ValueError(
                f"resume_from slice widths {dims_now} do not match the "
                f"artifact's fitted widths {dims_art} (per group, in org "
                f"order)")
    else:
        # original members must keep their fitted widths; joiners must fit
        # inside the group's fitted pad (stack_groups would otherwise grow
        # the pad and the completed rounds' params could not be stitched)
        for gi, n_old in enumerate(growth["old_sizes"]):
            if dims_now[gi][:n_old] != dims_art[gi]:
                raise ValueError(
                    f"resume_from group {gi} original-member slice widths "
                    f"{dims_now[gi][:n_old]} do not match the artifact's "
                    f"fitted widths {dims_art[gi]}")
            pad = art.group_pads[gi]
            wide = [w for w in dims_now[gi][n_old:]
                    if pad is not None and w > pad]
            if wide:
                raise ValueError(
                    f"orgs joining group {gi} have slice widths {wide} "
                    f"wider than the group's fitted pad ({pad}); the "
                    f"completed rounds' params were fit on {pad}-column "
                    f"stacks and cannot be re-padded — join with narrower "
                    f"slices or form a new group (different model config)")
    t0 = int(rs["t_next"])
    if config.rounds <= t0:
        raise ValueError(
            f"resume needs config.rounds > the artifact's {t0} completed "
            f"rounds (got rounds={config.rounds}); the artifact already "
            f"serves predictions for every fitted round prefix")
    if art.config is not None:
        # rounds/engine/data_shards are run-placement knobs, free to change
        # on resume; everything else (residual_dtype included) is protocol
        a = _dc.replace(art.config, rounds=0, engine="auto", data_shards=1)
        b = _dc.replace(config, rounds=0, engine="auto", data_shards=1)
        if a != b:
            diff = [f.name for f in _dc.fields(GALConfig)
                    if getattr(a, f.name) != getattr(b, f.name)]
            raise ValueError(
                f"resume config mismatch on {diff}: the resumed rounds "
                f"must draw from the same protocol as the fitted ones "
                f"(only rounds, engine and data_shards may change)")
    if loss_spec(loss) != loss_spec(art.loss):
        raise ValueError(
            f"resume loss mismatch: artifact was fit with "
            f"{loss_spec(art.loss)}, resume called with {loss_spec(loss)}")
    f = jnp.asarray(rs["f"])
    if tuple(f.shape) != (int(y.shape[0]), int(y.shape[-1])):
        raise ValueError(
            f"resume target shape {tuple(y.shape)} does not match the "
            f"artifact's ensemble carry {tuple(f.shape)} — resuming needs "
            f"the original training rows")
    # cheap data-identity gate: F^0 is a deterministic function of y
    # (mean/median/prior init), so a same-shape-but-different target —
    # where the restored carry would silently produce rounds no
    # uninterrupted fit could — is caught here for any label drift that
    # moves the init
    f0_now = np.asarray(loss.init_prediction(y))
    if not np.allclose(f0_now, np.asarray(art.f0), rtol=1e-6, atol=1e-7):
        raise ValueError(
            "resume target y does not look like the data the artifact was "
            "fit on (loss.init_prediction(y) differs from the artifact's "
            "F^0) — resuming needs the original training targets")
    saved_evals = dict(rs.get("f_evals") or {})
    names_now = sorted((eval_sets or {}).keys())
    if sorted(saved_evals) != names_now:
        raise ValueError(
            f"resume eval_sets {names_now} do not match the artifact's "
            f"saved eval carries {sorted(saved_evals)}; pass the same "
            f"eval sets the original fit used")
    for nm, fe in saved_evals.items():
        y_e = eval_sets[nm][1]
        if tuple(jnp.asarray(fe).shape) != (int(y_e.shape[0]),
                                            int(y.shape[-1])):
            raise ValueError(
                f"resume eval set {nm!r} has {int(y_e.shape[0])} rows, the "
                f"artifact's carry has {int(jnp.asarray(fe).shape[0])}")
    # fail on metric drift BEFORE the engine runs: the resumed rounds'
    # history columns must extend the artifact's exactly (the stitcher
    # re-checks, but by then the whole resumed fit has been paid for)
    expected = {"train_loss", *_LEDGER_COLS}
    for nm in (eval_sets or {}):
        expected.add(f"{nm}_loss")
        for mname in (metric_map or {}):
            expected.add(f"{nm}_{mname}")
    # "contributions" is a post-fit annotation (core/contrib.py), not a
    # per-round curve: it never blocks a resume, and the stitcher drops it
    # (the scores describe the artifact's org set up to ITS final round)
    if expected != set(art.history) - {"contributions"}:
        raise ValueError(
            f"resume history columns would not match the artifact's "
            f"(differing: "
            f"{sorted(expected ^ (set(art.history) - {'contributions'}))})"
            f"; resume with the same metrics/metric_fn the original fit "
            f"used")
    return {
        "t_next": t0,
        "f": f,
        "f_evals": {nm: jnp.asarray(v) for nm, v in saved_evals.items()},
        "key": jnp.asarray(rs["key"]),
        "active": jnp.asarray(rs["active"]),
        "state": jax.tree_util.tree_map(jnp.asarray,
                                        dict(rs.get("state") or {})),
    }, growth


def _stitch_resume(art: GALResult, new: GALResult, plan: ExecutionPlan,
                   growth=None) -> GALResult:
    """Concatenate an artifact's completed rounds with the freshly resumed
    ones into one seamless GALResult: etas/weights append, history columns
    extend (ledger columns verbatim, curve columns minus the restored-carry
    init row), fresh-fit group params concatenate on the round axis, and
    DMS group params are taken whole from the resumed carry (its stacked
    head buffer already spans every round).

    ``growth`` (from ``_prepare_resume``) marks a mid-fit join: orgs that
    joined at the resume point get a zeroed completed-round history — the
    artifact's per-round weights gain exact-zero columns, grown groups'
    params gain zero org-lanes, brand-new groups get zero rounds, and the
    stitched membership ledger records them absent — so ``predict`` at any
    pre-join prefix reproduces the original collaboration exactly. The
    artifact's post-fit "contributions" annotation (if any) is dropped:
    the scores describe the OLD org set up to the old final round."""
    art_hist = {c: v for c, v in art.history.items()
                if c != "contributions"}
    if set(art_hist) != set(new.history):
        raise ValueError(
            f"resumed history columns do not match the artifact's "
            f"(differing: {sorted(set(new.history) ^ set(art_hist))}); "
            f"resume with the same metrics/metric_fn the original fit "
            f"used")
    hist: Dict[str, List[float]] = {}
    for col, vals in new.history.items():
        old = list(art_hist[col])
        hist[col] = old + (list(vals) if col in _LEDGER_COLS
                           else list(vals[1:]))
    t_old = len(art.etas)
    m_new = sum(g.size for g in plan.groups)
    n_old_groups = (growth["n_old_groups"] if growth is not None
                    else plan.n_groups)
    old_sizes = (growth["old_sizes"] if growth is not None
                 else [g.size for g in plan.groups])
    group_params: List[Any] = []
    for gi, g in enumerate(plan.groups):
        if g.dms:
            group_params.append(new.group_params[gi])
            continue
        leaves_new, treedef = jax.tree_util.tree_flatten(
            new.group_params[gi])
        if gi >= n_old_groups:
            # a group born at the join: its completed rounds are exact
            # zeros (its orgs were absent, weight 0, so any value would be
            # inert — zeros keep the artifact readable)
            group_params.append(treedef.unflatten([
                jnp.concatenate([
                    jnp.zeros((t_old,) + jnp.asarray(b).shape[1:],
                              jnp.asarray(b).dtype), jnp.asarray(b)],
                    axis=0)
                for b in leaves_new]))
            continue
        # concatenate leaf-by-leaf in flatten order rather than with a
        # two-tree tree_map: a disk-loaded artifact holds tuples as lists
        # (the self-describing npz form), which flatten to the same leaf
        # sequence but not the same treedef as the fresh fit's params
        leaves_old = jax.tree_util.tree_leaves(art.group_params[gi])
        if len(leaves_old) != len(leaves_new):
            raise ValueError(
                f"resumed group {gi} params have {len(leaves_new)} leaves, "
                f"the artifact's have {len(leaves_old)} — the model "
                f"implementation changed since the artifact was saved")
        lanes_added = g.size - old_sizes[gi]
        stitched = []
        for a, b in zip(leaves_old, leaves_new):
            a = jnp.asarray(a)
            if lanes_added:
                # joiners' lanes over the completed rounds: exact zeros
                a = jnp.pad(a, ((0, 0), (0, lanes_added))
                            + ((0, 0),) * (a.ndim - 2))
            stitched.append(jnp.concatenate([a, jnp.asarray(b)], axis=0))
        group_params.append(treedef.unflatten(stitched))
    new.etas = list(art.etas) + list(new.etas)
    old_w = [jnp.asarray(w) for w in art.weights]
    if growth is not None:
        old_w = [jnp.pad(w, (0, m_new - growth["m_old"])) for w in old_w]
    new.weights = old_w + list(new.weights)
    new.history = hist
    new.group_params = group_params
    if plan.n_groups == 1 and not plan.has_dms:
        new.stacked_params = group_params[0]
    # stitched membership ledger: recorded history (joiners absent) in
    # front of the executed rows; stays None only when no membership story
    # exists on either side
    new_rows = new.membership
    if growth is not None or art.membership is not None \
            or new_rows is not None:
        m_old = growth["m_old"] if growth is not None else m_new
        old_rows = np.asarray(
            art.membership if art.membership is not None
            else np.ones((t_old, m_old), bool), bool)
        full = np.zeros((t_old, m_new), bool)
        full[:, :m_old] = old_rows
        exec_rows = np.asarray(
            new_rows if new_rows is not None
            else np.ones((len(new.etas) - t_old, m_new), bool), bool)
        new.membership = np.vstack([full, exec_rows]).tolist()
    return new


def _fit_python(rng, orgs, y, loss, config, eval_sets, metrics,
                membership=None) -> GALResult:
    """Reference interpreter-order engine (the conformance oracle).

    ``membership`` is the resolved bool (rounds, M) schedule or None. The
    oracle mirrors the compiled engines' membership semantics exactly:
    every org still runs its local fit each round (fresh-fit params stay
    round-aligned and the RNG chain stays org-independent) but an absent
    org's round is DEAD — exact-zero assistance weight, no ledger bytes,
    and for DMS orgs a skipped refit with a zero head in that round's
    slot (``Organization.fit_round(live=False)``)."""
    n = y.shape[0]
    k = y.shape[-1]
    f0 = loss.init_prediction(y)
    f_train = jnp.broadcast_to(f0, (n, k))
    alice_loss = lq_loss(config.alice_q)
    org_ids = jnp.asarray([org.index for org in orgs], jnp.uint32)

    result = GALResult(orgs=orgs, loss=loss, f0=f0, config=config)
    hist = result.history
    hist["train_loss"] = [float(loss(y, f_train))]
    f_evals = {}
    if eval_sets:
        for name, (xs_e, y_e) in eval_sets.items():
            f_evals[name] = jnp.broadcast_to(f0, (y_e.shape[0], k))
            hist[f"{name}_loss"] = [float(loss(y_e, f_evals[name]))]
            for mname, metric_fn in (metrics or {}).items():
                hist[f"{name}_{mname}"] = [
                    float(metric_fn(y_e, f_evals[name]))]
    # simulated per-round communication + model-memory ledgers (Table-14
    # convention, same formulas as the fused engines) — appended per
    # EXECUTED round so early stopping trims them like the fused engines do
    eval_ns = [int(y_e.shape[0]) for (_, y_e) in (eval_sets or {}).values()]
    from repro.core.engine import _resid_wire_bytes
    rb = _resid_wire_bytes(config)
    if membership is None:
        bcast_b, gather_b = gal_round_bytes(n, k, len(orgs), eval_ns,
                                            resid_dtype_bytes=rb)
        bcast_l = gather_l = None
    else:
        from repro.core.membership import membership_comm_ledger
        bcast_l, gather_l = membership_comm_ledger(membership, n, k,
                                                   eval_ns,
                                                   resid_dtype_bytes=rb)
    memories = gal_model_memories(config.rounds, [org.dms for org in orgs],
                                  membership=membership)
    hist["comm_broadcast_bytes"] = []
    hist["comm_gather_bytes"] = []
    hist["model_memories"] = []

    for t in range(config.rounds):
        row = None if membership is None else membership[t]
        rng, k_round = jax.random.split(rng)
        # 1. pseudo-residual
        residual = loss.residual(y, f_train)
        # 2. broadcast (privatized in hindsight if configured); under
        # residual_dtype="bf16" the wire carries bfloat16 — round-trip the
        # cast so the oracle sees exactly what the compiled engines see
        r_bcast = apply_privacy(
            jax.random.fold_in(k_round, 13), residual, config.privacy,
            alpha=config.privacy_alpha, n_intervals=config.privacy_intervals,
        )
        if rb == 2:
            r_bcast = r_bcast.astype(jnp.bfloat16).astype(residual.dtype)
        # 3. parallel local fits
        preds = jnp.stack([
            org.fit_round(jax.random.fold_in(k_round, org.index), r_bcast,
                          live=bool(row[m]) if row is not None else True)
            for m, org in enumerate(orgs)
        ])                                                    # (M, N, K)
        # 4. gradient assistance weights (masked over this round's live orgs)
        mask = None if row is None else jnp.asarray(row)
        if config.use_weights and len(orgs) > 1:
            w = fit_weights(
                jax.random.fold_in(k_round, 29), residual, preds, alice_loss,
                epochs=config.weight_epochs, lr=config.weight_lr,
                weight_decay=config.weight_decay,
                mask=mask, org_ids=org_ids,
            )
        else:
            w = uniform_weights(len(orgs), mask=mask)
        direction = jnp.einsum("m,mnk->nk", w, preds)
        # 5. line-search the gradient assisted learning rate
        eta = line_search(
            lambda e: loss(y, f_train + e * direction),
            method=config.eta_method, x0=config.eta0,
        )
        # 6. update the ensemble
        f_train = f_train + eta * direction
        result.etas.append(float(eta))
        result.weights.append(w)
        hist["train_loss"].append(float(loss(y, f_train)))
        hist["comm_broadcast_bytes"].append(
            bcast_b if membership is None else bcast_l[t])
        hist["comm_gather_bytes"].append(
            gather_b if membership is None else gather_l[t])
        hist["model_memories"].append(memories[t])
        if eval_sets:
            for name, (xs_e, y_e) in eval_sets.items():
                preds_e = jnp.stack([
                    org.predict_round(t, xs_e[m]) for m, org in enumerate(orgs)
                ])
                f_evals[name] = f_evals[name] + eta * jnp.einsum(
                    "m,mnk->nk", w, preds_e
                )
                hist[f"{name}_loss"].append(float(loss(y_e, f_evals[name])))
                for mname, metric_fn in (metrics or {}).items():
                    hist[f"{name}_{mname}"].append(
                        float(metric_fn(y_e, f_evals[name]))
                    )
        if (config.eta_stop_threshold > 0.0
                and abs(float(eta)) < config.eta_stop_threshold):
            break
    if membership is not None:
        result.membership = np.asarray(
            membership[:len(result.etas)], bool).tolist()
    return result
