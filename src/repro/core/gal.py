"""The GAL round engine (paper Algorithm 1), from Alice's perspective.

Per assistance round t:
  1. r^t   = -dL1(y, F^{t-1})/dF          (pseudo-residual, Alice)
  2. broadcast r^t (optionally privatized: DP/IP)          -> all orgs
  3. f_m^t = argmin_{f in F_m} E_N ell_m(r^t, f(x_m))       (orgs, parallel)
  4. w-hat = argmin_{w in simplex} E_N ell_1(r^t, sum w_m f_m^t)   (Alice)
  5. eta-hat = argmin_eta E_N L1(y, F^{t-1} + eta sum w_m f_m^t)   (Alice, L-BFGS)
  6. F^t = F^{t-1} + eta-hat * sum_m w-hat_m f_m^t

Prediction stage: F^T(x*) = F^0 + sum_t eta^t sum_m w_m^t f_m^t(x_m*).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.losses import Loss, lq_loss
from repro.core.organizations import Organization
from repro.core.privacy import apply_privacy
from repro.core.weights import fit_weights, uniform_weights
from repro.optim.lbfgs import line_search


@dataclass(frozen=True)
class GALConfig:
    rounds: int = 10
    # assisted learning rate (paper: L-BFGS line search; eta=1 const ablation)
    eta_method: str = "lbfgs"          # lbfgs | golden | constant
    eta0: float = 1.0
    eta_stop_threshold: float = 0.0    # stop assistance when |eta| drops below
    # gradient assistance weights (paper: softmax+Adam; uniform ablation)
    use_weights: bool = True
    weight_epochs: int = 100
    weight_lr: float = 0.1
    weight_decay: float = 5e-4
    # Alice's regression loss ell_1 used in the weight objective
    alice_q: float = 2.0
    # privacy on the broadcast residual (paper Sec 4.5)
    privacy: Optional[str] = None      # None | dp | ip
    privacy_alpha: float = 1.0
    privacy_intervals: int = 1


@dataclass
class GALResult:
    orgs: List[Organization]
    loss: Loss
    f0: jnp.ndarray                    # (1, K)
    etas: List[float] = field(default_factory=list)
    weights: List[jnp.ndarray] = field(default_factory=list)
    history: Dict[str, List[float]] = field(default_factory=dict)

    @property
    def rounds(self) -> int:
        return len(self.etas)

    def predict(self, xs: Sequence[jnp.ndarray], rounds: Optional[int] = None
                ) -> jnp.ndarray:
        """Prediction stage: assemble org outputs for new data xs[m]."""
        t_max = self.rounds if rounds is None else min(rounds, self.rounds)
        n = xs[0].shape[0]
        f = jnp.broadcast_to(self.f0, (n, self.f0.shape[-1]))
        for t in range(t_max):
            preds = jnp.stack([
                org.predict_round(t, xs[m]) for m, org in enumerate(self.orgs)
            ])
            f = f + self.etas[t] * jnp.einsum("m,mnk->nk", self.weights[t], preds)
        return f


def fit(rng: jax.Array, orgs: List[Organization], y: jnp.ndarray, loss: Loss,
        config: GALConfig = GALConfig(),
        eval_sets: Optional[Dict[str, tuple]] = None,
        metric_fn: Optional[Callable] = None) -> GALResult:
    """Run T assistance rounds. ``eval_sets`` maps name -> (xs_list, y) and is
    evaluated with the *prediction-stage* mechanics each round (paper's
    validation protocol), producing the per-round curves of Fig. 4."""
    n = y.shape[0]
    k = y.shape[-1]
    f0 = loss.init_prediction(y)
    f_train = jnp.broadcast_to(f0, (n, k))
    alice_loss = lq_loss(config.alice_q)

    result = GALResult(orgs=orgs, loss=loss, f0=f0)
    hist = result.history
    hist["train_loss"] = [float(loss(y, f_train))]
    f_evals = {}
    if eval_sets:
        for name, (xs_e, y_e) in eval_sets.items():
            f_evals[name] = jnp.broadcast_to(f0, (y_e.shape[0], k))
            hist[f"{name}_loss"] = [float(loss(y_e, f_evals[name]))]
            if metric_fn is not None:
                hist[f"{name}_metric"] = [float(metric_fn(y_e, f_evals[name]))]

    for t in range(config.rounds):
        rng, k_round = jax.random.split(rng)
        # 1. pseudo-residual
        residual = loss.residual(y, f_train)
        # 2. broadcast (privatized in hindsight if configured)
        r_bcast = apply_privacy(
            jax.random.fold_in(k_round, 13), residual, config.privacy,
            alpha=config.privacy_alpha, n_intervals=config.privacy_intervals,
        )
        # 3. parallel local fits
        preds = jnp.stack([
            org.fit_round(jax.random.fold_in(k_round, org.index), r_bcast)
            for org in orgs
        ])                                                    # (M, N, K)
        # 4. gradient assistance weights
        if config.use_weights and len(orgs) > 1:
            w = fit_weights(
                jax.random.fold_in(k_round, 29), residual, preds, alice_loss,
                epochs=config.weight_epochs, lr=config.weight_lr,
                weight_decay=config.weight_decay,
            )
        else:
            w = uniform_weights(len(orgs))
        direction = jnp.einsum("m,mnk->nk", w, preds)
        # 5. line-search the gradient assisted learning rate
        eta = line_search(
            lambda e: loss(y, f_train + e * direction),
            method=config.eta_method, x0=config.eta0,
        )
        # 6. update the ensemble
        f_train = f_train + eta * direction
        result.etas.append(float(eta))
        result.weights.append(w)
        hist["train_loss"].append(float(loss(y, f_train)))
        if eval_sets:
            for name, (xs_e, y_e) in eval_sets.items():
                preds_e = jnp.stack([
                    org.predict_round(t, xs_e[m]) for m, org in enumerate(orgs)
                ])
                f_evals[name] = f_evals[name] + eta * jnp.einsum(
                    "m,mnk->nk", w, preds_e
                )
                hist[f"{name}_loss"].append(float(loss(y_e, f_evals[name])))
                if metric_fn is not None:
                    hist[f"{name}_metric"].append(
                        float(metric_fn(y_e, f_evals[name]))
                    )
        if (config.eta_stop_threshold > 0.0
                and abs(float(eta)) < config.eta_stop_threshold):
            break
    return result
