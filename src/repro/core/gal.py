"""The GAL round engine (paper Algorithm 1), from Alice's perspective.

Per assistance round t:
  1. r^t   = -dL1(y, F^{t-1})/dF          (pseudo-residual, Alice)
  2. broadcast r^t (optionally privatized: DP/IP)          -> all orgs
  3. f_m^t = argmin_{f in F_m} E_N ell_m(r^t, f(x_m))       (orgs, parallel)
  4. w-hat = argmin_{w in simplex} E_N ell_1(r^t, sum w_m f_m^t)   (Alice)
  5. eta-hat = argmin_eta E_N L1(y, F^{t-1} + eta sum w_m f_m^t)   (Alice, L-BFGS)
  6. F^t = F^{t-1} + eta-hat * sum_m w-hat_m f_m^t

Prediction stage: F^T(x*) = F^0 + sum_t eta^t sum_m w_m^t f_m^t(x_m*).

Three executions of the same algorithm live here:

  * the **org-sharded multi-device path** (``repro.core.engine.fit_shard``):
    the org axis maps onto a real device mesh — one organization per device
    along an "org" axis; residual broadcast / fitted-value gather /
    weighted direction run as real collectives, with a per-round
    communication ledger in ``GALResult.history`` — selected automatically
    whenever the orgs are scan-compatible AND ``len(orgs)`` divides the
    (multi-)device count (``GALConfig.engine="shard"`` forces it);
  * the **scan fast path** (``repro.core.engine.fit_scan``): homogeneous
    orgs are vmapped over stacked slices and the T-round loop is one jitted
    ``lax.scan`` with a single host sync per ``fit`` — the automatic choice
    whenever every org shares a scan-safe model config but no org mesh is
    available; per-round params come back as a stacked pytree so
    ``predict`` is one vmap over (rounds x orgs);
  * the **Python reference path**: per-org dispatch in interpreter order,
    kept as the fallback for heterogeneous model-autonomy scenarios, Deep
    Model Sharing, noisy orgs, and non-traceable metrics
    (``GALConfig.engine="python"`` forces it).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import engine as engine_mod
from repro.core.losses import Loss, lq_loss
from repro.core.organizations import Organization
from repro.core.privacy import apply_privacy
from repro.core.weights import fit_weights, uniform_weights
from repro.optim.lbfgs import line_search


@dataclass(frozen=True)
class GALConfig:
    rounds: int = 10
    # assisted learning rate (paper: L-BFGS line search; eta=1 const ablation)
    eta_method: str = "lbfgs"          # lbfgs | golden | constant
    eta0: float = 1.0
    eta_stop_threshold: float = 0.0    # stop assistance when |eta| drops below
    # gradient assistance weights (paper: softmax+Adam; uniform ablation)
    use_weights: bool = True
    weight_epochs: int = 100
    weight_lr: float = 0.1
    weight_decay: float = 5e-4
    # Alice's regression loss ell_1 used in the weight objective
    alice_q: float = 2.0
    # privacy on the broadcast residual (paper Sec 4.5)
    privacy: Optional[str] = None      # None | dp | ip
    privacy_alpha: float = 1.0
    privacy_intervals: int = 1
    # engine selection: "auto" prefers the org-sharded multi-device path
    # (see engine.shard_eligible), then the fused scan path when the orgs
    # are homogeneous (see engine.scan_compatible), else the reference
    # loop; "python" forces the reference loop; "scan"/"shard" force a fast
    # path (raising when incompatible / no org mesh). NOTE the fast paths
    # trace metric_fn — it must be jax-traceable there.
    engine: str = "auto"               # auto | scan | shard | python


@dataclass
class GALResult:
    orgs: List[Organization]
    loss: Loss
    f0: jnp.ndarray                    # (1, K)
    etas: List[float] = field(default_factory=list)
    weights: List[jnp.ndarray] = field(default_factory=list)
    history: Dict[str, List[float]] = field(default_factory=dict)
    # scan fast path extras: per-round params as ONE stacked pytree with
    # leaves (T, M, ...), the shared model that applies them, and the padded
    # input geometry needed to stack prediction-stage slices.
    stacked_params: Any = None
    model: Any = None
    org_dims: Optional[List[int]] = None
    pad_to: Optional[int] = None
    engine: str = "python"

    @property
    def rounds(self) -> int:
        return len(self.etas)

    def predict(self, xs: Sequence[jnp.ndarray], rounds: Optional[int] = None
                ) -> jnp.ndarray:
        """Prediction stage: assemble org outputs for new data xs[m].

        Fast-path results evaluate the whole (rounds x orgs) ensemble with a
        nested vmap + one einsum; reference results loop per (round, org).
        """
        t_max = self.rounds if rounds is None else min(rounds, self.rounds)
        if self.stacked_params is not None:
            return engine_mod.stacked_predict(
                self.model, self.stacked_params, self.etas, self.weights,
                self.f0, xs, self.pad_to, t_max, org_dims=self.org_dims,
            )
        return self.predict_legacy(xs, rounds)

    def predict_legacy(self, xs: Sequence[jnp.ndarray],
                       rounds: Optional[int] = None) -> jnp.ndarray:
        """Per-(round, org) Python assembly of the prediction stage — the
        reference the stacked path is measured against (benchmarks, serving).
        Needs per-org round params: call ``unpack_to_orgs()`` first on
        fast-path results, and pad xs to ``pad_to`` columns there.

        Reads LIVE Organization state: a later ``gal.fit``/``al.fit`` on
        the same org objects resets it (see
        ``Organization.reset_round_state``) and invalidates this path for
        results of earlier fits — refit fresh orgs to keep old results."""
        t_max = self.rounds if rounds is None else min(rounds, self.rounds)
        n = xs[0].shape[0]
        f = jnp.broadcast_to(self.f0, (n, self.f0.shape[-1]))
        for t in range(t_max):
            preds = jnp.stack([
                org.predict_round(t, xs[m]) for m, org in enumerate(self.orgs)
            ])
            f = f + self.etas[t] * jnp.einsum("m,mnk->nk", self.weights[t], preds)
        return f

    def unpack_to_orgs(self) -> None:
        """Copy fast-path per-round params back into the Organization objects
        so legacy per-(round, org) flows (``predict_round``) work. The params
        were fit on slices zero-padded to ``pad_to`` columns — pad inputs with
        ``repro.data.partition.pad_and_stack`` before applying them."""
        if self.stacked_params is None:
            return
        for i, org in enumerate(self.orgs):
            org._round_params = [
                jax.tree_util.tree_map(
                    lambda l, t=t, i=i: l[t, i], self.stacked_params)
                for t in range(self.rounds)
            ]


def fit(rng: jax.Array, orgs: List[Organization], y: jnp.ndarray, loss: Loss,
        config: GALConfig = GALConfig(),
        eval_sets: Optional[Dict[str, tuple]] = None,
        metric_fn: Optional[Callable] = None) -> GALResult:
    """Run T assistance rounds. ``eval_sets`` maps name -> (xs_list, y) and is
    evaluated with the *prediction-stage* mechanics each round (paper's
    validation protocol), producing the per-round curves of Fig. 4."""
    if config.engine not in ("auto", "scan", "shard", "python"):
        raise ValueError(f"unknown engine {config.engine!r}")
    for org in orgs:
        org.reset_round_state()  # a refit must not read stale round params
    compatible = engine_mod.scan_compatible(orgs, eval_sets)
    shard_ok = compatible and engine_mod.shard_eligible(orgs, eval_sets)
    if config.engine == "scan" and not compatible:
        raise ValueError(
            "engine='scan' needs homogeneous scan-safe organizations "
            "(same model config, no DMS/noise, stackable slices)")
    if config.engine == "shard" and not compatible:
        raise ValueError(
            "engine='shard' needs homogeneous scan-safe organizations "
            "(same model config, no DMS/noise, stackable slices)")
    if (config.engine != "python" and compatible and eval_sets
            and metric_fn is not None
            and not engine_mod.metric_traceable(metric_fn, eval_sets)):
        if config.engine in ("scan", "shard"):
            raise ValueError(
                f"engine={config.engine!r} requires a jax-traceable "
                "metric_fn (it runs under jit inside the fused round "
                "step); this metric_fn failed jax.eval_shape")
        compatible = shard_ok = False  # host-side metric: fall back cleanly
    if config.engine == "shard" or (config.engine == "auto" and shard_ok):
        return _fit_shard(rng, orgs, y, loss, config, eval_sets, metric_fn)
    if config.engine != "python" and compatible:
        return _fit_scan(rng, orgs, y, loss, config, eval_sets, metric_fn)
    return _fit_python(rng, orgs, y, loss, config, eval_sets, metric_fn)


def _fit_scan(rng, orgs, y, loss, config, eval_sets, metric_fn) -> GALResult:
    out = engine_mod.fit_scan(rng, orgs, y, loss, config, eval_sets, metric_fn)
    return _fast_result(orgs, y, loss, out, "scan")


def _fit_shard(rng, orgs, y, loss, config, eval_sets, metric_fn) -> GALResult:
    out = engine_mod.fit_shard(rng, orgs, y, loss, config, eval_sets,
                               metric_fn)
    return _fast_result(orgs, y, loss, out, "shard")


def _fast_result(orgs, y, loss, out, engine: str) -> GALResult:
    return GALResult(
        orgs=orgs, loss=loss, f0=loss.init_prediction(y),
        etas=out["etas"], weights=out["weights"], history=out["history"],
        stacked_params=out["params"], model=orgs[0].model,
        org_dims=out["dims"], pad_to=out["pad_to"], engine=engine,
    )


def _fit_python(rng, orgs, y, loss, config, eval_sets, metric_fn) -> GALResult:
    """Reference interpreter-order engine (heterogeneous fallback)."""
    n = y.shape[0]
    k = y.shape[-1]
    f0 = loss.init_prediction(y)
    f_train = jnp.broadcast_to(f0, (n, k))
    alice_loss = lq_loss(config.alice_q)

    result = GALResult(orgs=orgs, loss=loss, f0=f0)
    hist = result.history
    hist["train_loss"] = [float(loss(y, f_train))]
    f_evals = {}
    if eval_sets:
        for name, (xs_e, y_e) in eval_sets.items():
            f_evals[name] = jnp.broadcast_to(f0, (y_e.shape[0], k))
            hist[f"{name}_loss"] = [float(loss(y_e, f_evals[name]))]
            if metric_fn is not None:
                hist[f"{name}_metric"] = [float(metric_fn(y_e, f_evals[name]))]

    for t in range(config.rounds):
        rng, k_round = jax.random.split(rng)
        # 1. pseudo-residual
        residual = loss.residual(y, f_train)
        # 2. broadcast (privatized in hindsight if configured)
        r_bcast = apply_privacy(
            jax.random.fold_in(k_round, 13), residual, config.privacy,
            alpha=config.privacy_alpha, n_intervals=config.privacy_intervals,
        )
        # 3. parallel local fits
        preds = jnp.stack([
            org.fit_round(jax.random.fold_in(k_round, org.index), r_bcast)
            for org in orgs
        ])                                                    # (M, N, K)
        # 4. gradient assistance weights
        if config.use_weights and len(orgs) > 1:
            w = fit_weights(
                jax.random.fold_in(k_round, 29), residual, preds, alice_loss,
                epochs=config.weight_epochs, lr=config.weight_lr,
                weight_decay=config.weight_decay,
            )
        else:
            w = uniform_weights(len(orgs))
        direction = jnp.einsum("m,mnk->nk", w, preds)
        # 5. line-search the gradient assisted learning rate
        eta = line_search(
            lambda e: loss(y, f_train + e * direction),
            method=config.eta_method, x0=config.eta0,
        )
        # 6. update the ensemble
        f_train = f_train + eta * direction
        result.etas.append(float(eta))
        result.weights.append(w)
        hist["train_loss"].append(float(loss(y, f_train)))
        if eval_sets:
            for name, (xs_e, y_e) in eval_sets.items():
                preds_e = jnp.stack([
                    org.predict_round(t, xs_e[m]) for m, org in enumerate(orgs)
                ])
                f_evals[name] = f_evals[name] + eta * jnp.einsum(
                    "m,mnk->nk", w, preds_e
                )
                hist[f"{name}_loss"].append(float(loss(y_e, f_evals[name])))
                if metric_fn is not None:
                    hist[f"{name}_metric"].append(
                        float(metric_fn(y_e, f_evals[name]))
                    )
        if (config.eta_stop_threshold > 0.0
                and abs(float(eta)) < config.eta_stop_threshold):
            break
    return result
