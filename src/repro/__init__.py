"""repro: production-grade JAX framework reproducing GAL (NeurIPS 2022)."""
__version__ = "1.0.0"
