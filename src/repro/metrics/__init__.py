from repro.metrics.metrics import accuracy, mad, auroc, metric_for_task
