from repro.metrics.metrics import (METRICS, accuracy, auroc, get_metric,
                                   mad, metric_for_task)
