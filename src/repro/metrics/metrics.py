"""Evaluation metrics matching the paper: Accuracy, MAD, AUROC.

Every metric here is a pure-jnp ``(y, f) -> scalar`` callable, registered
in the ``METRICS`` registry so the GAL engines can evaluate them INSIDE the
traced round step (device-side eval curves, one host sync per fit —
``gal.fit(..., metrics=("accuracy", "auroc"))``). There is no host-side
metric escape hatch any more: a metric that cannot trace under
``jax.eval_shape`` is rejected up front on every engine, with this registry
named as the fix.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.utils.registry import Registry

METRICS: Registry = Registry("metric")


@METRICS.register("accuracy")
def accuracy(y_onehot: jnp.ndarray, f_logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(
        (jnp.argmax(f_logits, -1) == jnp.argmax(y_onehot, -1)).astype(jnp.float32)
    ) * 100.0


@METRICS.register("mad")
def mad(y: jnp.ndarray, f: jnp.ndarray) -> jnp.ndarray:
    """Mean absolute deviation (paper's regression metric)."""
    return jnp.mean(jnp.abs(y - f))


@METRICS.register("auroc")
def auroc(y: jnp.ndarray, scores: jnp.ndarray) -> jnp.ndarray:
    """Rank-based AUROC for binary labels y in {0,1}, scores = logits.
    Mann-Whitney U with EXACT average ranks for ties: each score's rank is
    the mean of the 1-based positions its tie group spans, so quantized
    logits / saturated sigmoids score identically regardless of sample
    order (a bare argsort rank is order-dependent under ties). The double
    ``searchsorted`` keeps the whole thing traceable, so AUROC eval curves
    run inside the fused round scan."""
    y = y.reshape(-1)
    s = scores.reshape(-1)
    s_sorted = jnp.sort(s)
    lo = jnp.searchsorted(s_sorted, s, side="left")
    hi = jnp.searchsorted(s_sorted, s, side="right")
    ranks = 0.5 * (lo + hi + 1).astype(s.dtype)
    n_pos = jnp.sum(y)
    n_neg = y.shape[0] - n_pos
    sum_pos = jnp.sum(ranks * y)
    u = sum_pos - n_pos * (n_pos + 1) / 2.0
    return jnp.where((n_pos > 0) & (n_neg > 0), u / (n_pos * n_neg), 0.5)


def get_metric(name: str):
    """Resolve a registry metric by name (the ``gal.fit(metrics=...)``
    entries)."""
    return METRICS.get(name)


def metric_for_task(task: str):
    if task == "classification":
        return accuracy
    if task == "regression":
        return mad
    if task == "binary":
        return auroc
    raise ValueError(f"unknown task {task!r}")
