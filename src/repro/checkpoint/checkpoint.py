"""Checkpointing and the GAL artifact lifecycle: fit once, serve forever.

Two layers live here:

* **pytree round-trips** (``save_pytree`` / ``load_pytree``): npz-based, no
  orbax offline. Paths are flattened with jax.tree_util key paths so any
  nested dict/list/tuple pytree of arrays round-trips exactly (bf16 leaves
  ride as uint16 views). ``load_pytree`` is *self-describing*: called
  without a ``like`` template it rebuilds the nested dict/list structure
  from the flattened key paths themselves, so an artifact can be loaded in
  a process that never held the original pytree (tuples come back as
  lists — identical under ``tree_map``, which is all the engines do with
  them).

* **the GAL artifact** (``save_artifact`` / ``load_artifact``): the
  versioned on-disk form of a compiled-engine ``GALResult`` — everything
  the Prediction Stage and a resumed fit need to outlive the fitting
  process:

    - ``manifest.json`` — the ``gal-artifact/v1`` schema tag, the
      ``GALConfig``, Alice's loss and every group's local loss *as specs*
      (ell_q losses by exponent, registry losses by name, custom callables
      by ``__name__`` — re-resolved at load), the execution-plan manifest
      (``repro.core.plan.plan_to_manifest``: group indices / org ids /
      model specs / noise sigmas / DMS flags), per-group stacking geometry,
      etas, the full history (comm/memory ledgers as exact ints), and the
      resume cursor ``t_next``;
    - ``arrays.npz`` — one self-describing pytree holding ``f0``, the
      stacked assistance weights ``(T, M)``, every group's stacked round
      params, and the round-scan resume carry (ensemble state ``f``,
      per-eval-set carries, the post-scan RNG key, the early-stop flag,
      and the DMS extractor/head/residual-history buffers).

  ``load_artifact`` returns a ``GALResult`` with no Organizations attached:
  ``predict`` / ``predict_proba``-style serving works immediately (the
  grouped prediction path needs only the plan + stacked params), and
  ``gal.fit(..., resume_from=...)`` extends the collaboration from round
  ``t_next`` once the caller re-supplies the private org data. Models are
  re-instantiated from the ``repro.models.zoo`` registry; custom models
  and custom losses are resolved through the ``models=`` / ``losses=``
  maps (the artifact stores only their names — private code never touches
  disk, matching the paper's "no sharing of models" contract).

The legacy ``GALCheckpoint`` (per-round json+npz dumps) predates the
compiled engines and remains for the python reference loop's round-level
dumps; new code should use the artifact API.
"""
from __future__ import annotations

import dataclasses
import json
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "|"

# the artifact schema this build writes AND the only one it reads; bump on
# any incompatible layout change so stale artifacts fail loudly at load
ARTIFACT_SCHEMA = "gal-artifact/v1"
ARTIFACT_MANIFEST = "manifest.json"
ARTIFACT_ARRAYS = "arrays.npz"


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return f"d:{k.key}"
    if hasattr(k, "idx"):
        return f"i:{k.idx}"
    return f"d:{k}"


def _empty_container_paths(tree: Any) -> List[tuple]:
    """Paths of zero-leaf containers (empty dict/list/tuple, None): they
    flatten to nothing, so the self-describing loader needs explicit
    markers to rebuild them (and to keep list indices from shifting)."""
    found: List[tuple] = []

    def walk(node, prefix):
        if node is None:
            found.append((prefix, "none"))
        elif isinstance(node, dict):
            if not node:
                found.append((prefix, "dict"))
            for k, v in node.items():
                walk(v, prefix + [f"d:{k}"])
        elif isinstance(node, (list, tuple)):
            if not node:
                found.append((prefix, "list"))
            for i, v in enumerate(node):
                walk(v, prefix + [f"i:{i}"])

    walk(tree, [])
    return found


def save_pytree(path: str | Path, tree: Any) -> None:
    """Save an arbitrary pytree of arrays/scalars to one .npz file.

    Dict keys become path components joined on ``"|"`` with a ``"@bf16"``
    dtype marker suffix, so keys that collide with either are rejected
    loudly here — the self-describing loader would otherwise rebuild a
    silently wrong structure (e.g. an eval set named ``"a|b"``). Empty
    dict/list/tuple nodes and ``None`` are recorded as explicit markers
    (``__empties__``) so the template-free load reproduces them instead of
    silently dropping them."""
    def check_parts(parts):
        for part in parts:
            if _SEP in part[2:] or part.endswith("@bf16"):
                raise ValueError(
                    f"pytree key {part[2:]!r} collides with the flattened "
                    f"path encoding ({_SEP!r} separator / '@bf16' dtype "
                    f"marker); rename it (e.g. the eval-set name)")
        return parts

    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = check_parts([_key_str(k) for k in kp])
        key = _SEP.join(parts) or "__root__"
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":     # npz cannot store bf16
            key = key + "@bf16"
            arr = arr.view(np.uint16)
        flat[key] = arr
    empties = [[_SEP.join(check_parts(parts)), kind]
               for parts, kind in _empty_container_paths(tree)]
    # record the treedef structure for exact reconstruction
    structure = jax.tree_util.tree_structure(tree)
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez(path, __treedef__=np.frombuffer(
        str(structure).encode(), dtype=np.uint8),
        __empties__=np.frombuffer(
            json.dumps(empties).encode(), dtype=np.uint8), **flat)


_EMPTY_SENTINEL = "__empty__"
_EMPTY_VALUES = {"dict": dict, "list": list, "none": lambda: None}


def _unflatten_self_describing(data) -> Any:
    """Rebuild the nested dict/list pytree from flattened key paths alone.

    ``d:`` components become dict keys, ``i:`` components list indices
    (tuples were flattened with ``i:`` too and come back as lists —
    equivalent under ``tree_map``). A lone ``__root__`` key is a bare
    leaf. bf16 leaves are recognized by the ``@bf16`` suffix; zero-leaf
    containers (empty dict/list, None) are restored from the
    ``__empties__`` markers, keeping list indices aligned."""
    items = []
    for key in data.files:
        if key in ("__treedef__", "__empties__"):
            continue
        arr = data[key]
        if key.endswith("@bf16"):
            key = key[:-len("@bf16")]
            arr = jnp.asarray(arr).view(jnp.bfloat16)
        else:
            arr = jnp.asarray(arr)
        items.append((key, arr))
    empties = (json.loads(bytes(data["__empties__"]).decode())
               if "__empties__" in data.files else [])
    if not items and len(empties) == 1 and empties[0][0] == "":
        return _EMPTY_VALUES[empties[0][1]]()      # whole tree is empty
    if len(items) == 1 and items[0][0] == "__root__" and not empties:
        return items[0][1]

    root: Dict[str, Any] = {}
    for key, arr in items:
        parts = key.split(_SEP)
        node = root
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = arr
    for key, kind in empties:
        parts = key.split(_SEP)
        node = root
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = {_EMPTY_SENTINEL: kind}

    def finalize(node):
        if not isinstance(node, dict):
            return node
        if set(node) == {_EMPTY_SENTINEL}:
            return _EMPTY_VALUES[node[_EMPTY_SENTINEL]]()
        if node and all(k.startswith("i:") for k in node):
            idx = sorted(node, key=lambda k: int(k[2:]))
            return [finalize(node[k]) for k in idx]
        return {k[2:]: finalize(v) for k, v in node.items()}

    return finalize(root)


def load_pytree(path: str | Path, like: Any = None) -> Any:
    """Restore a pytree saved by ``save_pytree``.

    With ``like`` given, its structure AND leaf dtypes are authoritative
    (exact reconstruction including tuples and custom dtypes). Without it,
    the structure is rebuilt from the flattened key paths — dicts and
    lists come back as themselves, tuples as lists — which is what
    ``load_artifact`` uses to read an artifact in a fresh process."""
    data = np.load(Path(path), allow_pickle=False)
    if like is None:
        return _unflatten_self_describing(data)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    flat_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    out = []
    for (kp, leaf) in flat_paths:
        key = _SEP.join(_key_str(k) for k in kp) or "__root__"
        if key + "@bf16" in data:
            arr = jnp.asarray(data[key + "@bf16"]).view(jnp.bfloat16)
        else:
            arr = jnp.asarray(data[key])
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------------------------------------------------------
# spec codecs: models and losses as manifest-serializable identities
# --------------------------------------------------------------------------

def _jsonify(obj: Any) -> Any:
    """JSON-safe copy: tuples -> lists, numpy scalars -> Python scalars."""
    if isinstance(obj, dict):
        return {str(k): _jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonify(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    return obj


def model_spec(model: Any) -> Dict[str, Any]:
    """The manifest identity of a local model: zoo models serialize as
    (registry name, dataclass fields) and reconstruct exactly; duck-typed
    external models serialize by class name only and must be re-supplied
    at load (``models={name: instance}``) — private model code never
    touches the artifact."""
    from repro.models.zoo import ZOO
    for name in ZOO.names():
        if type(model) is ZOO.get(name):
            fields = (dataclasses.asdict(model)
                      if dataclasses.is_dataclass(model) else {})
            return {"kind": "zoo", "name": name, "fields": _jsonify(fields)}
    return {"kind": "custom", "name": type(model).__name__}


def model_from_spec(spec: Dict[str, Any],
                    models: Optional[Dict[str, Any]] = None) -> Any:
    """Inverse of ``model_spec``; ``models`` resolves custom names."""
    if spec["kind"] == "zoo":
        from repro.models.zoo import ZOO
        cls = ZOO.get(spec["name"])
        fields = {k: tuple(v) if isinstance(v, list) else v
                  for k, v in spec.get("fields", {}).items()}
        return cls(**fields)
    name = spec["name"]
    if models and name in models:
        return models[name]
    raise ValueError(
        f"artifact references custom model {name!r}: its code is not "
        f"stored (the paper's no-model-sharing contract) — pass "
        f"load_artifact(..., models={{{name!r}: <instance>}})")


def loss_spec(loss: Any) -> Dict[str, Any]:
    """The manifest identity of a loss: ell_q losses by exponent,
    registry ``Loss`` objects by name, custom callables by ``__name__``
    (re-resolved at load via ``losses={name: fn}``)."""
    if loss is None:
        return {"kind": "none"}
    q = getattr(loss, "q", None)
    if q is not None:
        return {"kind": "lq", "q": float(q)}
    from repro.core.losses import LOSSES
    name = getattr(loss, "name", None)
    if name is not None and name in LOSSES:
        return {"kind": "registry", "name": name}
    return {"kind": "custom",
            "name": getattr(loss, "__name__", type(loss).__name__)}


def loss_from_spec(spec: Dict[str, Any],
                   losses: Optional[Dict[str, Callable]] = None) -> Any:
    """Inverse of ``loss_spec``; ``losses`` resolves custom names."""
    kind = spec["kind"]
    if kind == "none":
        return None
    if kind == "lq":
        from repro.core.losses import lq_loss
        return lq_loss(spec["q"])
    if kind == "registry":
        from repro.core.losses import get_loss
        return get_loss(spec["name"])
    name = spec["name"]
    if losses and name in losses:
        return losses[name]
    raise ValueError(
        f"artifact references custom loss {name!r}: its code is not "
        f"stored — pass load_artifact(..., losses={{{name!r}: <callable>}})")


# --------------------------------------------------------------------------
# the GAL artifact: save / load a complete compiled-engine GALResult
# --------------------------------------------------------------------------

def save_artifact(result: Any, path: str | Path) -> Path:
    """Persist a compiled-engine ``GALResult`` as a versioned artifact dir.

    Writes ``manifest.json`` + ``arrays.npz`` (see the module docstring
    for the exact field inventory). Only compiled-engine results can be
    saved: a python-reference result holds its round params inside live
    ``Organization`` objects, which the artifact deliberately never
    serializes — refit with ``engine="scan"/"grouped"/"shard"`` (or
    ``"auto"``) to get a self-contained result."""
    from repro.core.plan import plan_to_manifest
    if result.plan is None or result.group_params is None:
        raise ValueError(
            "only compiled-engine results can be saved as artifacts: this "
            f"result ran engine={result.engine!r}, whose round params live "
            "inside the Organization objects — refit with engine='auto' "
            "(or 'scan'/'grouped'/'shard') for a self-contained result")
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    # the manifest is the commit marker (written LAST): drop any stale one
    # first so a crash mid-save leaves an unloadable directory — never a
    # loadable mix of old manifest and new arrays
    (path / ARTIFACT_MANIFEST).unlink(missing_ok=True)

    n_orgs = result.plan.n_orgs
    weights = (np.stack([np.asarray(w) for w in result.weights])
               if result.weights else np.zeros((0, n_orgs), np.float32))
    # a DMS group's fitted ensemble IS its resume carry (the shared
    # extractor + stacked head buffer): when the carry is saved below,
    # store that pytree once and let load_artifact alias it back into
    # group_params — otherwise every DMS artifact would double its
    # dominant payload
    dms_in_carry = result.resume_state is not None
    arrays: Dict[str, Any] = {
        "f0": result.f0,
        "weights": weights,
        "group_params": {
            f"g{gi}": gp for gi, gp in enumerate(result.group_params)
            if not (dms_in_carry and result.plan.groups[gi].dms)},
    }
    t_next = None
    eval_names: List[str] = []
    if result.resume_state is not None:
        rs = result.resume_state
        t_next = int(rs["t_next"])
        eval_names = sorted(rs.get("f_evals", {}))
        arrays["resume"] = {
            "f": rs["f"], "f_evals": dict(rs.get("f_evals", {})),
            "key": rs["key"], "active": rs["active"],
            "state": dict(rs.get("state", {})),
        }
    save_pytree(path / ARTIFACT_ARRAYS, arrays)

    manifest = {
        "schema": ARTIFACT_SCHEMA,
        "engine": result.engine,
        "config": (_jsonify(dataclasses.asdict(result.config))
                   if result.config is not None else None),
        "loss": loss_spec(result.loss),
        "plan": plan_to_manifest(result.plan, model_spec, loss_spec),
        "group_dims": _jsonify(result.group_dims),
        "group_pads": _jsonify(result.group_pads),
        "etas": [float(e) for e in result.etas],
        "history": _jsonify(result.history),
        "rounds": int(result.rounds),
        "n_orgs": int(n_orgs),
        "t_next": t_next,
        "eval_names": eval_names,
        # executed-round membership ledger (None for all-live fits) —
        # resume needs it to reconstruct joiners' zero-weight history and
        # DMS orgs' dead slots; optional so pre-membership artifacts load
        "membership": result.membership,
    }
    (path / ARTIFACT_MANIFEST).write_text(json.dumps(manifest, indent=2))
    return path


def artifact_info(path: str | Path) -> Dict[str, Any]:
    """Cheap artifact peek: read and validate ``manifest.json`` WITHOUT
    touching ``arrays.npz``. This is what a serving registry uses to
    validate a tenant registration and describe its inventory — a full
    ``load_artifact`` materializes every round's stacked params, which is
    exactly the cost lazy loading defers."""
    path = Path(path)
    man_path = path / ARTIFACT_MANIFEST
    if not man_path.exists():
        raise ValueError(f"{path} is not a GAL artifact directory "
                         f"(missing {ARTIFACT_MANIFEST})")
    manifest = json.loads(man_path.read_text())
    schema = manifest.get("schema")
    if schema != ARTIFACT_SCHEMA:
        raise ValueError(
            f"unsupported artifact schema {schema!r}: this build reads "
            f"{ARTIFACT_SCHEMA!r} (re-fit and re-save, or load with a "
            f"matching build)")
    return {
        "schema": schema,
        "engine": manifest.get("engine"),
        "rounds": int(manifest.get("rounds", 0)),
        "n_orgs": int(manifest.get("n_orgs", 0)),
        "n_groups": len(manifest.get("plan", {}).get("groups", [])),
        "t_next": manifest.get("t_next"),
        "eval_names": list(manifest.get("eval_names", [])),
        "group_dims": manifest.get("group_dims"),
        "group_pads": manifest.get("group_pads"),
    }


def load_artifact(path: str | Path,
                  losses: Optional[Dict[str, Callable]] = None,
                  models: Optional[Dict[str, Any]] = None) -> Any:
    """Load a ``save_artifact`` directory back into a ``GALResult``.

    The result has NO Organizations attached (``orgs=[]``): ``predict``
    works immediately through the grouped stacked-params path and is
    bitwise-identical to the in-memory result at every round prefix;
    ``unpack_to_orgs``/``predict_legacy`` need live orgs and stay off
    limits until the caller re-attaches them. Pass the loaded result (or
    the path itself) as ``gal.fit(..., resume_from=...)`` together with
    the original org data to extend the collaboration from round
    ``t_next``.

    ``losses`` / ``models`` resolve custom (non-registry) identities the
    manifest stores by name only; unknown names raise."""
    from repro.core.gal import GALConfig, GALResult
    from repro.core.plan import plan_from_manifest
    path = Path(path)
    man_path = path / ARTIFACT_MANIFEST
    if not man_path.exists():
        raise ValueError(f"{path} is not a GAL artifact directory "
                         f"(missing {ARTIFACT_MANIFEST})")
    manifest = json.loads(man_path.read_text())
    schema = manifest.get("schema")
    if schema != ARTIFACT_SCHEMA:
        raise ValueError(
            f"unsupported artifact schema {schema!r}: this build reads "
            f"{ARTIFACT_SCHEMA!r} (re-fit and re-save, or load with a "
            f"matching build)")

    plan = plan_from_manifest(
        manifest["plan"],
        lambda s: model_from_spec(s, models),
        lambda s: loss_from_spec(s, losses))
    loss = loss_from_spec(manifest["loss"], losses)
    arrays = load_pytree(path / ARTIFACT_ARRAYS)

    weights = [w for w in arrays["weights"]]
    history = {k: list(v) for k, v in manifest["history"].items()}
    resume_state = None
    if manifest.get("t_next") is not None:
        rs = arrays.get("resume", {})
        resume_state = {
            "t_next": int(manifest["t_next"]),
            "f": rs["f"],
            "f_evals": dict(rs.get("f_evals", {})),
            "key": rs["key"],
            "active": rs["active"],
            "state": dict(rs.get("state", {})),
        }
    stored_gp = arrays.get("group_params", {})
    group_params = [
        # DMS groups are stored once, inside the resume carry (see
        # save_artifact) — alias the shared pytree back
        stored_gp.get(f"g{gi}", (resume_state or {}).get("state",
                                                         {}).get(f"g{gi}"))
        for gi in range(plan.n_groups)
    ]
    config = (GALConfig(**manifest["config"])
              if manifest.get("config") else None)
    single = plan.n_groups == 1 and not plan.has_dms
    group_dims = manifest["group_dims"]
    group_pads = manifest["group_pads"]
    return GALResult(
        orgs=[], loss=loss, f0=arrays["f0"],
        etas=[float(e) for e in manifest["etas"]],
        weights=weights, history=history,
        stacked_params=group_params[0] if single else None,
        model=plan.groups[0].model if single else None,
        org_dims=group_dims[0] if single else None,
        pad_to=group_pads[0] if single else None,
        plan=plan, group_params=group_params,
        group_dims=group_dims, group_pads=group_pads,
        mesh_devices=0, engine=manifest["engine"],
        config=config, resume_state=resume_state,
        membership=([list(map(bool, row))
                     for row in manifest["membership"]]
                    if manifest.get("membership") else None),
    )


# --------------------------------------------------------------------------
# legacy per-round checkpoints (python reference loop)
# --------------------------------------------------------------------------

@dataclass
class GALCheckpoint:
    """Round-resumable GAL collaboration state (legacy per-round dumps;
    the compiled engines use ``save_artifact``/``load_artifact``)."""
    directory: Path

    def __init__(self, directory: str | Path):
        self.directory = Path(directory)
        self.directory.mkdir(parents=True, exist_ok=True)

    def save_round(self, t: int, eta: float, weights, org_params: List[Any]
                   ) -> None:
        meta = {"round": t, "eta": float(eta),
                "weights": [float(w) for w in np.asarray(weights)]}
        (self.directory / f"round_{t:04d}.json").write_text(json.dumps(meta))
        for m, p in enumerate(org_params):
            if p is not None:
                save_pytree(self.directory / f"round_{t:04d}_org{m}.npz", p)

    def latest_round(self) -> int:
        rounds = sorted(self.directory.glob("round_*.json"))
        if not rounds:
            return -1
        return int(re.search(r"round_(\d+)", rounds[-1].name).group(1))

    def load_round_meta(self, t: int) -> Dict:
        return json.loads(
            (self.directory / f"round_{t:04d}.json").read_text())

    def load_org_params(self, t: int, m: int, like: Any) -> Any:
        return load_pytree(self.directory / f"round_{t:04d}_org{m}.npz", like)
