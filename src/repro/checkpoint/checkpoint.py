"""Checkpointing: npz-based pytree save/restore + round-resumable GAL state.

No orbax offline; paths are flattened with jax.tree_util key paths so any
nested dict/list/tuple pytree of arrays round-trips exactly. The GAL protocol
checkpoints per assistance round (etas, weights, per-org round params), so an
interrupted collaboration resumes at the last completed round — the
production property the paper's "few rounds" claim depends on.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "|"


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return f"d:{k.key}"
    if hasattr(k, "idx"):
        return f"i:{k.idx}"
    return f"d:{k}"


def save_pytree(path: str | Path, tree: Any) -> None:
    """Save an arbitrary pytree of arrays/scalars to one .npz file."""
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_key_str(k) for k in kp) or "__root__"
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":     # npz cannot store bf16
            key = key + "@bf16"
            arr = arr.view(np.uint16)
        flat[key] = arr
    # record the treedef structure for exact reconstruction
    structure = jax.tree_util.tree_structure(tree)
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez(path, __treedef__=np.frombuffer(
        str(structure).encode(), dtype=np.uint8), **flat)


def load_pytree(path: str | Path, like: Any) -> Any:
    """Restore a pytree saved by save_pytree; ``like`` provides structure."""
    data = np.load(Path(path), allow_pickle=False)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    flat_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    out = []
    for (kp, leaf) in flat_paths:
        key = _SEP.join(_key_str(k) for k in kp) or "__root__"
        if key + "@bf16" in data:
            arr = jnp.asarray(data[key + "@bf16"]).view(jnp.bfloat16)
        else:
            arr = jnp.asarray(data[key])
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclass
class GALCheckpoint:
    """Round-resumable GAL collaboration state."""
    directory: Path

    def __init__(self, directory: str | Path):
        self.directory = Path(directory)
        self.directory.mkdir(parents=True, exist_ok=True)

    def save_round(self, t: int, eta: float, weights, org_params: List[Any]
                   ) -> None:
        meta = {"round": t, "eta": float(eta),
                "weights": [float(w) for w in np.asarray(weights)]}
        (self.directory / f"round_{t:04d}.json").write_text(json.dumps(meta))
        for m, p in enumerate(org_params):
            if p is not None:
                save_pytree(self.directory / f"round_{t:04d}_org{m}.npz", p)

    def latest_round(self) -> int:
        rounds = sorted(self.directory.glob("round_*.json"))
        if not rounds:
            return -1
        return int(re.search(r"round_(\d+)", rounds[-1].name).group(1))

    def load_round_meta(self, t: int) -> Dict:
        return json.loads(
            (self.directory / f"round_{t:04d}.json").read_text())

    def load_org_params(self, t: int, m: int, like: Any) -> Any:
        return load_pytree(self.directory / f"round_{t:04d}_org{m}.npz", like)
