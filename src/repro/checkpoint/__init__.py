from repro.checkpoint.checkpoint import (ARTIFACT_SCHEMA, GALCheckpoint,
                                         artifact_info, load_artifact,
                                         load_pytree, save_artifact,
                                         save_pytree)
