import os
if os.environ.get("REPRO_FORCE_DEVICES"):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count="
                               f"{os.environ['REPRO_FORCE_DEVICES']}")
"""Production serving launcher: the GAL Prediction Stage at one organization
— batched single-token decode against a KV/state cache on a mesh.

Example (CPU container):
  REPRO_FORCE_DEVICES=8 PYTHONPATH=src python -m repro.launch.serve \
      --arch rwkv6-7b --smoke --mesh 2,4 --batch 8 --steps 16
"""
import argparse
import time

import jax
import jax.numpy as jnp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="1,1")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=256)
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.configs.base import InputShape
    from repro.launch import sharding as shd
    from repro.launch.mesh import make_test_mesh
    from repro.models import pspec as act_hints
    from repro.models import transformer as tfm
    from repro.train.steps import make_serve_step

    cfg = get_arch(args.arch, smoke=args.smoke)
    shape = tuple(int(x) for x in args.mesh.split(","))
    mesh = make_test_mesh(shape, ("data", "model"))
    act_hints.set_mesh(mesh)

    key = jax.random.PRNGKey(0)
    params = tfm.init_params(key, cfg)
    params = jax.device_put(params, shd.params_shardings(cfg, mesh, params))
    enc = None
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(
            key, (args.batch, cfg.num_frames, cfg.d_model), jnp.float32)
        enc = tfm.encode(params, cfg, frames)
    cache = tfm.init_cache(cfg, args.batch, args.cache_len, encoder_out=enc)
    ishape = InputShape("serve", args.cache_len, args.batch, "decode")
    c_sh = shd.cache_shardings(cfg, mesh, jax.eval_shape(lambda: cache),
                               ishape)
    cache = jax.device_put(cache, c_sh)

    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    tok = jax.random.randint(key, (args.batch, 1), 0, cfg.vocab)
    with mesh:
        logits, cache = serve(params, cache, tok)  # compile
        t0 = time.time()
        for _ in range(args.steps):
            logits, cache = serve(params, cache, tok)
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        jax.block_until_ready(logits)
    dt = (time.time() - t0) / args.steps
    print(f"arch={cfg.arch} mesh={dict(mesh.shape)} batch={args.batch} "
          f"cache={args.cache_len}: {dt * 1e3:.2f} ms/token "
          f"finite={bool(jnp.all(jnp.isfinite(logits)))}")


if __name__ == "__main__":
    main()
