"""Production serving launcher: the GAL Prediction Stage.

Two serving modes:

  * LM decode (default): batched single-token decode at one organization
    against a KV/state cache on a mesh.
  * ``--gal-ensemble``: the full multi-org Prediction Stage — fit a
    homogeneous GAL ensemble on a synthetic vertical split, then serve
    batched predictions through the stacked-round fast path (ONE vmap over
    rounds x orgs per request) and report latency vs the legacy
    per-(round, org) Python assembly. ``--engine shard`` fits on the
    org-sharded multi-device engine (one org per device along an "org"
    mesh axis) and reports its per-round communication ledger.
    ``--hetero`` switches to the paper's model-autonomy setting: a
    GB–SVM-style mixed-model org set fit on the grouped fused engine,
    printing the planner's per-group composition alongside the serve
    latency. ``--dms`` fits Deep Model Sharing organizations (paper
    Sec. 4.2/5: one shared extractor + T stacked heads per org) on the
    grouped engine and prints the model-memory ledger's Tx saving next to
    the fresh-fit baseline. ``--save DIR`` persists the fitted ensemble as
    a versioned artifact (``repro.checkpoint.save_artifact``) after the
    fit; ``--load DIR`` skips the fit entirely and serves the artifact —
    fit once, serve forever: the loaded ensemble's jitted predict path is
    compiled once and cached across every subsequent request.

Examples (CPU container):
  REPRO_FORCE_DEVICES=8 PYTHONPATH=src python -m repro.launch.serve \
      --arch rwkv6-7b --smoke --mesh 2,4 --batch 8 --steps 16
  PYTHONPATH=src python -m repro.launch.serve --gal-ensemble \
      --rounds 8 --orgs 4 --batch 256 --steps 32
  REPRO_FORCE_DEVICES=4 PYTHONPATH=src python -m repro.launch.serve \
      --gal-ensemble --engine shard --rounds 8 --orgs 4 --batch 256
  PYTHONPATH=src python -m repro.launch.serve --gal-ensemble --hetero \
      --rounds 8 --orgs 4 --batch 256
  PYTHONPATH=src python -m repro.launch.serve --gal-ensemble \
      --rounds 8 --orgs 4 --save /tmp/gal-artifact          # fit once
  PYTHONPATH=src python -m repro.launch.serve --gal-ensemble \
      --orgs 4 --load /tmp/gal-artifact                     # serve forever
  PYTHONPATH=src python -m repro.launch.serve --service \
      --tenants 2 --clients 8 --requests 256               # the service

``--service`` runs the multi-tenant inference service (``repro.serve``,
docs/serving.md): an artifact registry of ``--tenants`` collaborations
served through per-tenant bucketed micro-batching, driven by
``--clients`` concurrent closed-loop clients, reporting batched
throughput/latency against the one-request-at-a-time baseline.

NOTE: the ``REPRO_FORCE_DEVICES`` shim below must run before the first jax
operation in the process (see repro/utils/force_devices.py), so it sits
ahead of every other import.
"""
from repro.utils.force_devices import apply_force_devices
apply_force_devices()

import argparse
import time

import jax
import jax.numpy as jnp


def measure_request_path(fn, steps: int):
    """Time a jitted request path two ways (all clocks monotonic):

    * **blocked latency** — block on every result before issuing the
      next request: the time ONE caller waits for its answer.
    * **pipelined throughput** — dispatch all ``steps`` requests and
      block once at the end: what the async dispatch pipeline sustains.

    The old serve loop dispatched asynchronously and blocked only on the
    final result but printed the number as "ms/req" — that is the
    throughput figure, NOT the latency a caller sees; this helper
    reports both, under their real names. Returns ``(latency_s,
    throughput_s)`` per request, or ``(None, None)`` when ``steps == 0``
    (compile-only runs measure nothing).
    """
    if steps <= 0:
        return None, None
    t0 = time.perf_counter()
    for _ in range(steps):
        jax.block_until_ready(fn())
    lat = (time.perf_counter() - t0) / steps
    t0 = time.perf_counter()
    out = None
    for _ in range(steps):
        out = fn()
    jax.block_until_ready(out)
    thr = (time.perf_counter() - t0) / steps
    return lat, thr


def _fmt_ms(seconds) -> str:
    return "n/a (steps=0)" if seconds is None else f"{seconds * 1e3:.2f} ms"


def gal_ensemble_serve(args) -> None:
    """Serve the stacked-round GAL ensemble; print ms/request for the fused
    vmap path next to the legacy per-(round, org) loop. With
    ``--engine shard`` the fit runs org-sharded across devices and the
    per-round communication ledger is printed. ``--save`` persists the
    fitted ensemble as an artifact after the (cold) fit; ``--load`` serves
    a saved artifact with NO fit at all — the warm-start path a production
    deployment restarts on."""
    import numpy as np
    from repro.core import gal
    from repro.core.gal import GALConfig
    from repro.core.losses import get_loss
    from repro.core.organizations import make_orgs
    from repro.data.partition import split_features
    from repro.data.synthetic import make_regression, train_test_split
    from repro.models.zoo import Linear

    from repro.models.zoo import KernelRidge, MLP, StumpBoost

    rng_np = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)

    req_widths = None
    if args.load:
        from repro.checkpoint import load_artifact
        t0 = time.perf_counter()
        res = load_artifact(args.load)
        dt_load = time.perf_counter() - t0
        if res.plan is not None and res.plan.n_orgs != args.orgs:
            # the artifact knows its own org count — no need to re-type it
            print(f"gal-ensemble: the artifact was fit on "
                  f"{res.plan.n_orgs} organizations; serving those "
                  f"(--orgs {args.orgs} ignored)")
            args.orgs = res.plan.n_orgs
        if any(p is None for p in res.group_pads):
            raise SystemExit(
                "--load in this demo CLI serves tabular artifacts only "
                "(this one was fit on higher-rank slices); load it with "
                "repro.checkpoint.load_artifact and call predict directly")
        # request slices must reproduce the artifact's per-org widths, in
        # org order — the registry recovers them from the plan geometry
        from repro.serve import request_widths
        req_widths = request_widths(res)
        print(f"gal-ensemble WARM start: loaded {args.load} in "
              f"{dt_load * 1e3:.0f} ms (engine={res.engine} "
              f"rounds={res.rounds}, no refit — the artifact outlives "
              f"the fitting process; --rounds/--engine describe fits and "
              f"are ignored here)")

    d_total = 4 * args.orgs if req_widths is None else sum(req_widths)
    ds = make_regression(rng_np, n=512, d=d_total)
    train, test = train_test_split(ds, rng_np)

    if not args.load:
        xs = split_features(train.x, args.orgs)
        engine = args.engine
        dms = False
        if args.dms:
            # Deep Model Sharing (paper Sec. 4.2/5): one shared extractor +
            # T stacked heads per org, fused by the grouped engine's carry
            models, dms = MLP((16,), epochs=20), True
            if engine in ("scan", "shard"):
                engine = "grouped"  # the DMS carry is grouped territory
        elif args.hetero:
            # model autonomy (paper Sec. 4.2): alternate GB / SVM stand-ins
            # so the planner fuses a mixed-model set into one compiled loop
            models = [StumpBoost(n_stumps=20) if i % 2 == 0
                      else KernelRidge() for i in range(args.orgs)]
            if engine in ("scan", "shard"):
                engine = "grouped"  # single-group engines cannot mix models
        else:
            models = Linear()
        t0 = time.perf_counter()
        orgs = make_orgs(xs, models, dms=dms)
        cfg = GALConfig(rounds=args.rounds, engine=engine)
        res = gal.fit(key, orgs, train.y, get_loss("mse"), cfg)
        dt_fit = time.perf_counter() - t0
        print(f"gal-ensemble COLD start: fit {args.rounds} rounds in "
              f"{dt_fit:.2f} s (engine={res.engine})")
        if args.contributions:
            from repro.core.contrib import leave_one_out, truncated_shapley
            cut = args.rounds // 2
            t0 = time.perf_counter()
            if args.contributions == "shapley":
                rep = truncated_shapley(key, orgs, train.y, get_loss("mse"),
                                        cfg, t0=cut, full=res)
            else:
                rep = leave_one_out(key, orgs, train.y, get_loss("mse"),
                                    cfg, t0=cut, full=res)
            dt_c = time.perf_counter() - t0
            print(f"gal-ensemble contributivity ({rep['method']}, "
                  f"value={rep['value']} over rounds {cut}..{args.rounds}, "
                  f"{rep['refits']} counterfactual refits resumed from the "
                  f"round-{cut} carry, {dt_c:.2f} s):")
            print(f"  v_full={rep['v_full']:.4f}  v_empty={rep['v_empty']:.4f}")
            for oid, s in zip(rep["org_ids"], rep["scores"]):
                bar = "#" * max(0, min(40, int(
                    40 * s / max(abs(max(rep["scores"], key=abs)), 1e-12))))
                print(f"  org {oid}: {s:+12.4f}  {bar}")
        if args.save:
            from repro.checkpoint import save_artifact
            t0 = time.perf_counter()
            save_artifact(res, args.save)
            print(f"gal-ensemble artifact saved to {args.save} in "
                  f"{(time.perf_counter() - t0) * 1e3:.0f} ms — serve it with "
                  f"--load {args.save} (no refit) or extend it with "
                  f"gal.fit(..., resume_from={args.save!r})")
    if "model_memories" in res.history:
        from repro.core.protocol_sim import gal_model_memories
        fresh = gal_model_memories(res.rounds, [False] * args.orgs)
        live = res.history["model_memories"][-1]
        dms = res.plan.has_dms if res.plan is not None else args.dms
        print(f"gal-ensemble model memories ({'DMS' if dms else 'fresh'}): "
              f"{live} live copies after {res.rounds} rounds "
              f"(fresh-fit baseline {fresh[-1]}; "
              f"{fresh[-1] / max(live, 1):.1f}x saving)")
    if res.plan is not None:
        sharded = (f", group stacks sharded over {res.mesh_devices} devices"
                   if res.mesh_devices else "")
        print(f"gal-ensemble plan ({res.engine}): "
              f"{res.plan.describe()}{sharded}")
    if "comm_broadcast_bytes" in res.history:
        tag = "collective" if res.engine == "shard" else "simulated"
        print(f"gal-ensemble comm ledger ({res.engine}, {tag}): "
              f"broadcast={sum(res.history['comm_broadcast_bytes']):.0f} B "
              f"gathered={sum(res.history['comm_gather_bytes']):.0f} B "
              f"over {res.rounds} rounds x {len(jax.devices())} devices")

    from repro.data.partition import split_channels
    slices = (split_channels(test.x, req_widths) if req_widths is not None
              else split_features(test.x, args.orgs))
    xs_req = [jnp.tile(x, (max(1, args.batch // x.shape[0]) + 1, 1)
                       )[:args.batch] for x in slices]
    # ONE jit compilation, cached across every subsequent request — for a
    # loaded artifact this is the entire warm-up cost of the deployment.
    # The compile call also BINDS the output, so --steps 0 still has a
    # result to verify against (the old loop left `out` unbound there).
    serve_fast = jax.jit(lambda xq: res.predict(xq))
    out = jax.block_until_ready(serve_fast(xs_req))       # compile
    lat_fast, thr_fast = measure_request_path(
        lambda: serve_fast(xs_req), args.steps)

    if args.load:
        # a loaded artifact has no live Organizations: the legacy
        # per-(round, org) loop does not apply — report the served path
        print(f"gal-ensemble orgs={args.orgs} rounds={res.rounds} "
              f"batch={args.batch}: stacked latency={_fmt_ms(lat_fast)}/req "
              f"pipelined={_fmt_ms(thr_fast)}/req "
              f"(warm-loaded artifact, jitted predict cached across "
              f"requests)")
        return

    res.unpack_to_orgs()                                  # legacy loop path
    # per-round params were fit at each GROUP's pad width: pad request
    # slices per group before the per-(round, org) assembly
    from repro.data.partition import stack_groups, unstack_groups
    index_groups = [g.indices for g in res.plan.groups]
    stacks, _, _ = stack_groups(xs_req, index_groups, pad_tos=res.group_pads)
    xs_padded = unstack_groups(stacks, index_groups)

    out_legacy = jax.block_until_ready(res.predict_legacy(xs_padded))
    lat_legacy, thr_legacy = measure_request_path(
        lambda: res.predict_legacy(xs_padded), args.steps)

    drift = float(jnp.max(jnp.abs(out - out_legacy)))
    speedup = ("n/a" if lat_fast is None
               else f"{lat_legacy / max(lat_fast, 1e-9):.1f}x")
    print(f"gal-ensemble orgs={args.orgs} rounds={args.rounds} "
          f"batch={args.batch}: "
          f"stacked latency={_fmt_ms(lat_fast)}/req "
          f"pipelined={_fmt_ms(thr_fast)}/req "
          f"legacy latency={_fmt_ms(lat_legacy)}/req "
          f"speedup={speedup} max_drift={drift:.2e}")


def service_serve(args) -> None:
    """``--service``: the multi-tenant inference service (docs/serving.md)
    under a concurrent closed-loop load harness. Registers ``--tenants``
    collaborations (fit fresh per-tenant, or ``--load DIR`` registered
    once per tenant), warms each tenant's bucket cache, then prints the
    batched service's throughput/latency next to the one-request-at-a-
    time baseline on the same artifacts."""
    import numpy as np
    from repro.core import gal
    from repro.core.gal import GALConfig
    from repro.core.losses import get_loss
    from repro.core.organizations import make_orgs
    from repro.data.partition import split_features
    from repro.data.synthetic import make_regression, train_test_split
    from repro.models.zoo import Linear
    from repro.serve import (ArtifactRegistry, GALService, run_load,
                             run_serial)

    registry = ArtifactRegistry(max_batch=args.max_batch)
    tenants = [f"tenant{i}" for i in range(args.tenants)]
    t0 = time.perf_counter()
    for ti, tenant in enumerate(tenants):
        if args.load:
            registry.register(tenant, args.load)
            continue
        rng = np.random.default_rng(ti)
        key = jax.random.PRNGKey(ti)
        ds = make_regression(rng, n=256, d=4 * args.orgs)
        train, _ = train_test_split(ds, rng)
        xs = split_features(train.x, args.orgs)
        res = gal.fit(key, make_orgs(xs, Linear()), train.y,
                      get_loss("mse"),
                      GALConfig(rounds=args.rounds, engine="scan"))
        registry.register(tenant, res)
    src = f"loaded {args.load}" if args.load else "fit fresh"
    print(f"gal-service: {len(tenants)} tenants registered ({src}) in "
          f"{time.perf_counter() - t0:.2f} s")

    # synthesize single-row requests from each tenant's fitted geometry;
    # waves of `clients` consecutive requests share a tenant so the
    # batcher sees full per-tenant complements
    tenant_rows = {}
    for ti, tenant in enumerate(tenants):
        widths = registry.get(tenant).widths
        if any(w is None for w in widths):
            raise SystemExit("--service serves tabular artifacts only")
        rng = np.random.default_rng(100 + ti)
        tenant_rows[tenant] = [
            rng.normal(size=(64, w)).astype(np.float32) for w in widths]
    requests = []
    for i in range(args.requests):
        tenant = tenants[(i // max(args.clients, 1)) % len(tenants)]
        row = i % 64
        requests.append(
            (tenant, [x[row:row + 1] for x in tenant_rows[tenant]]))

    svc = GALService(registry, deadline_s=args.deadline_ms / 1e3,
                     flush_rows=args.flush_rows)
    t0 = time.perf_counter()
    buckets = sum(svc.warmup(t) for t in tenants)
    print(f"gal-service: warmed {buckets} bucket compilations "
          f"(max_batch={args.max_batch}) in "
          f"{time.perf_counter() - t0:.2f} s — no live request pays a "
          f"compile")
    try:
        serial = run_serial(registry, requests[:max(args.clients,
                                                    args.requests // 4)])
        load = run_load(svc, requests, clients=args.clients,
                        depth=args.depth)
    finally:
        svc.close()
    print(f"gal-service serial (1 client, blocked): "
          f"{serial['requests_per_sec']:.0f} req/s "
          f"p50={serial['p50_ms']:.2f} ms")
    print(f"gal-service batched ({args.clients} clients x depth "
          f"{args.depth}): {load['requests_per_sec']:.0f} req/s "
          f"p50={load['p50_ms']:.2f} ms p99={load['p99_ms']:.2f} ms "
          f"speedup={load['requests_per_sec'] / serial['requests_per_sec']:.1f}x")
    for tenant, st in sorted(svc.stats()["tenants"].items()):
        print(f"  {tenant}: {st['requests']} requests in {st['batches']} "
              f"launches ({st['rows_per_batch']:.1f} rows/launch)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="1,1")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--gal-ensemble", action="store_true",
                    help="serve the stacked-round GAL Prediction Stage")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--orgs", type=int, default=4)
    ap.add_argument("--engine", default="scan",
                    choices=("auto", "scan", "shard", "grouped"),
                    help="--gal-ensemble fit engine; 'shard' places one org "
                         "per device (needs orgs | device count); 'grouped' "
                         "is the planner-driven fused engine for mixed "
                         "model sets")
    ap.add_argument("--hetero", action="store_true",
                    help="--gal-ensemble with a mixed GB/SVM-style model "
                         "set (model autonomy) fused by the org execution "
                         "planner; prints the per-group composition")
    ap.add_argument("--dms", action="store_true",
                    help="--gal-ensemble with Deep Model Sharing orgs "
                         "(one shared extractor + stacked per-round heads) "
                         "on the grouped engine; prints the model-memory "
                         "ledger's Tx saving")
    ap.add_argument("--save", default=None, metavar="DIR",
                    help="--gal-ensemble: persist the fitted ensemble as a "
                         "versioned artifact directory after the fit "
                         "(repro.checkpoint.save_artifact)")
    ap.add_argument("--load", default=None, metavar="DIR",
                    help="--gal-ensemble: SKIP the fit and serve a saved "
                         "artifact (fit once, serve forever); the jitted "
                         "predict path is compiled once and cached across "
                         "requests")
    ap.add_argument("--contributions", default=None,
                    choices=("loo", "shapley"),
                    help="--gal-ensemble: after the cold fit, score each "
                         "org's contributivity (leave-one-out or truncated "
                         "Shapley) via counterfactual refits resumed from "
                         "the mid-fit carry, and print the per-org table")
    ap.add_argument("--service", action="store_true",
                    help="run the multi-tenant inference service "
                         "(registry + bucketed batching, repro.serve) "
                         "under a concurrent load harness; combine with "
                         "--load DIR to serve a saved artifact per tenant")
    ap.add_argument("--tenants", type=int, default=2,
                    help="--service: registered collaborations")
    ap.add_argument("--clients", type=int, default=8,
                    help="--service: concurrent load-generator threads")
    ap.add_argument("--requests", type=int, default=256,
                    help="--service: total requests across all clients")
    ap.add_argument("--depth", type=int, default=4,
                    help="--service: requests each client keeps in flight")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="--service: largest bucket shape (jit cache holds "
                         "one compile per power-of-two bucket up to this)")
    ap.add_argument("--deadline-ms", type=float, default=10.0,
                    help="--service: max time a pending request waits "
                         "before its batch is flushed anyway")
    ap.add_argument("--flush-rows", type=int, default=16,
                    help="--service: rows that trigger an immediate flush")
    args = ap.parse_args()

    if args.load:
        conflicts = [flag for flag, on in (("--save", args.save),
                                           ("--hetero", args.hetero),
                                           ("--dms", args.dms),
                                           ("--contributions",
                                            args.contributions)) if on]
        if conflicts:
            ap.error(f"--load serves an already-fitted artifact; "
                     f"{'/'.join(conflicts)} choose fit-time behavior — "
                     f"drop them (or drop --load to fit)")

    if args.service:
        for flag, on in (("--save", args.save), ("--hetero", args.hetero),
                         ("--dms", args.dms),
                         ("--contributions", args.contributions)):
            if on:
                ap.error(f"--service serves fitted artifacts; {flag} "
                         f"chooses fit-time behavior — drop it")
        service_serve(args)
        return
    if args.gal_ensemble:
        gal_ensemble_serve(args)
        return
    if args.arch is None:
        ap.error("--arch is required unless --gal-ensemble is given")

    from repro.configs import get_arch
    from repro.configs.base import InputShape
    from repro.launch import sharding as shd
    from repro.launch.mesh import make_device_mesh
    from repro.models import pspec as act_hints
    from repro.models import transformer as tfm
    from repro.train.steps import make_serve_step

    cfg = get_arch(args.arch, smoke=args.smoke)
    shape = tuple(int(x) for x in args.mesh.split(","))
    mesh = make_device_mesh(shape, ("data", "model"))
    act_hints.set_mesh(mesh)

    key = jax.random.PRNGKey(0)
    params = tfm.init_params(key, cfg)
    params = jax.device_put(params, shd.params_shardings(cfg, mesh, params))
    enc = None
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(
            key, (args.batch, cfg.num_frames, cfg.d_model), jnp.float32)
        enc = tfm.encode(params, cfg, frames)
    cache = tfm.init_cache(cfg, args.batch, args.cache_len, encoder_out=enc)
    ishape = InputShape("serve", args.cache_len, args.batch, "decode")
    c_sh = shd.cache_shardings(cfg, mesh, jax.eval_shape(lambda: cache),
                               ishape)
    cache = jax.device_put(cache, c_sh)

    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    tok = jax.random.randint(key, (args.batch, 1), 0, cfg.vocab)
    with mesh:
        # the compile call binds `logits`, so --steps 0 (compile-only)
        # still has a result to check for finiteness
        logits, cache = serve(params, cache, tok)  # compile
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for _ in range(args.steps):
            logits, cache = serve(params, cache, tok)
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        jax.block_until_ready(logits)
    dt = ((time.perf_counter() - t0) / args.steps if args.steps > 0
          else None)
    print(f"arch={cfg.arch} mesh={dict(mesh.shape)} batch={args.batch} "
          f"cache={args.cache_len}: {_fmt_ms(dt)}/token "
          f"finite={bool(jnp.all(jnp.isfinite(logits)))}")


if __name__ == "__main__":
    main()
