"""Sharding policy: map parameter paths / input kinds to PartitionSpecs.

Tensor parallelism on "model": attention q-heads, MLP hidden, MoE experts
(1/shard at E=16), mamba inner channels, rwkv heads, and the vocab dim of the
unembedding + residual/logits. Batch parallelism on ("pod","data").

GQA note: when n_kv_heads < model-axis size the KV projections stay
replicated (standard TP>KV practice, DESIGN.md Sec. 4).
long_500k note: batch=1 cannot shard on data — the KV window / state heads
shard on "model" and the data axis idles (recorded in the roofline analysis).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.launch.mesh import axis_size, data_axes


def _pad(spec_tail, ndim):
    """Right-align a spec tail over the trailing dims; leading dims None
    (stacked-layer axes)."""
    tail = list(spec_tail)
    lead = [None] * (ndim - len(tail))
    return P(*(lead + tail))


def _path_str(path) -> str:
    return "/".join(
        str(getattr(k, "key", getattr(k, "idx", k))) for k in path
    )


def _add_fsdp(spec: P, leaf, mesh) -> P:
    """ZeRO-3 style: shard one remaining matrix dim of every >=2D weight on
    "data" (params + Adam m/v then fit 132B on 256 chips; XLA all-gathers the
    weight just-in-time per layer). Only the trailing 2 dims are considered —
    stacked-layer leading dims stay unsharded so lax.scan slicing is local."""
    nd = leaf.ndim
    if nd < 2:
        return spec
    d_size = axis_size(mesh, "data")
    entries = list(spec) + [None] * (nd - len(spec))
    cands = [d for d in (nd - 1, nd - 2)
             if entries[d] is None and leaf.shape[d] % d_size == 0]
    if not cands:
        return spec
    best = max(cands, key=lambda d: leaf.shape[d])
    entries[best] = "data"
    return P(*entries)


def param_pspec(cfg: ModelConfig, mesh, path, leaf, fsdp: bool = True) -> P:
    name = _path_str(path)
    nd = leaf.ndim
    m = axis_size(mesh, "model")

    def col():   # shard output/column dim
        return _pad([None, "model"], nd)

    def row():   # shard input/row (contraction) dim
        return _pad(["model", None], nd)

    def rep():
        return P()

    last = name.rsplit("/", 1)[-1]
    if "embed" in name:
        if last == "tok":
            # (V, d) sharded on d: the residual stream then flip-flops between
            # d-sharded (carry/stash) and batch-sharded (attention/MLP) each
            # layer. Measured trade (llama3 train_4k): the flip costs ~290 GiB
            # of per-layer activation all-gathers, BUT the 2D-sharded
            # (batch x d) stash is 16x smaller than a d-replicated one
            # (14.9 vs 29+ GiB peak) and total HBM traffic is lower. The
            # gather-heavy layout still wins the roofline max-term. A
            # replicated table (rep()) flips the trade — kept as the
            # documented alternative (EXPERIMENTS.md SS Perf).
            return _pad([None, "model"], nd) if cfg.d_model % m == 0 else rep()
        if last == "unembed":                      # (d, V): logits sharded on V
            return _pad([None, "model"], nd) if cfg.vocab % m == 0 else rep()
        return rep()
    if last == "proj":                              # vlm projector (d, d)
        return col()
    if "moe" in name:
        if last == "router":
            return rep()
        return _pad(["model", None, None], nd)      # (E, ., .): expert parallel
    if "attn" in name or "cross" in name:
        if last == "wq":
            return col() if (cfg.n_heads * cfg.hd) % m == 0 else rep()
        if last in ("wk", "wv"):
            return col() if cfg.n_kv_heads % m == 0 else rep()
        if last == "wo":
            return row() if (cfg.n_heads * cfg.hd) % m == 0 else rep()
        return rep()                                # qk norms, ln scales
    if "mlp" in name:
        if last in ("w_gate", "w_up"):
            return col() if cfg.d_ff % m == 0 else rep()
        if last == "w_down":
            return row() if cfg.d_ff % m == 0 else rep()
        return rep()
    if "mamba" in name:
        d_in = cfg.ssm_expand * cfg.d_model
        if last in ("w_z", "w_x"):
            return col() if d_in % m == 0 else rep()
        if last == "out_proj":
            return row() if d_in % m == 0 else rep()
        return rep()                                # w_B/w_C/w_dt/conv/scalars
    if "tmix" in name:
        if last in ("wr", "wk", "wv", "wg"):
            return col() if cfg.d_model % m == 0 else rep()
        if last == "wo":
            return row() if cfg.d_model % m == 0 else rep()
        return rep()
    if "cmix" in name:
        if last == "wk":
            return col() if cfg.d_ff % m == 0 else rep()
        if last == "wv":
            return row() if cfg.d_ff % m == 0 else rep()
        return rep()
    return rep()                                    # norms and everything else


def params_shardings(cfg: ModelConfig, mesh, abstract_params,
                     fsdp: bool = True):
    def one(path, leaf):
        name = _path_str(path)
        spec = param_pspec(cfg, mesh, path, leaf)
        # the token table is gathered by token id — a row-sharded (V on
        # "data") table trips the SPMD partitioner inside scans, so it is
        # exempt from FSDP (it is d-sharded on "model" already)
        if fsdp and not name.endswith("tok"):
            spec = _add_fsdp(spec, leaf, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, abstract_params)


def opt_state_shardings(cfg: ModelConfig, mesh, abstract_opt_state,
                        abstract_params):
    """Adam m/v mirror the parameter shardings; step is replicated."""
    del abstract_opt_state  # adam-family: {"step", "m", "v"}
    p_sh = params_shardings(cfg, mesh, abstract_params)
    rep = NamedSharding(mesh, P())
    return {"step": rep, "m": p_sh, "v": p_sh}


def batch_shardings(cfg: ModelConfig, mesh, batch_specs) -> Dict[str, Any]:
    dp = data_axes(mesh)
    dp_size = axis_size(mesh, dp)
    out = {}
    for k, sds in batch_specs.items():
        b = sds.shape[0]
        batch_axis = dp if b % dp_size == 0 else None
        if k == "residual":
            spec = P(batch_axis, None, "model" if cfg.vocab % axis_size(
                mesh, "model") == 0 else None)
        elif k in ("tokens", "labels"):
            spec = P(batch_axis, None)
        elif k in ("patches", "frames"):
            spec = P(batch_axis, None, None)
        elif k in ("residual_idx", "residual_vals"):
            spec = P(batch_axis, None, None)
        else:
            spec = P(*([batch_axis] + [None] * (len(sds.shape) - 1)))
        out[k] = NamedSharding(mesh, spec)
    return out


def cache_shardings(cfg: ModelConfig, mesh, cache_specs, shape: InputShape):
    """KV/state cache shardings for serve_step."""
    dp = data_axes(mesh)
    dp_size = axis_size(mesh, dp)
    m = axis_size(mesh, "model")
    b = shape.global_batch
    batch_ok = b % dp_size == 0

    def one(path, leaf):
        name = _path_str(path)
        last = name.rsplit("/", 1)[-1]
        nd = leaf.ndim
        if last in ("k", "v"):
            # (L, B, Ssize, KV, hd): batch on data + head_dim on model keeps
            # the 275 GB decode_32k caches ~1 GiB/device; attention contracts
            # hd -> small score all-reduce instead of resharding the cache
            hd_dim = leaf.shape[-1]
            ssize = leaf.shape[2]
            hd_ax = "model" if hd_dim % m == 0 else None
            if batch_ok:
                return NamedSharding(mesh, _pad([dp, None, None, hd_ax], nd))
            if ssize % m == 0:
                return NamedSharding(mesh, _pad([None, "model", None, None], nd))
            return NamedSharding(mesh, P())
        if last == "h":  # mamba state (U, per, B, H, N, P)
            hdim = leaf.shape[-3]
            if batch_ok:
                return NamedSharding(mesh, _pad([dp, None, None, None], nd))
            if hdim % m == 0:
                return NamedSharding(mesh, _pad(["model", None, None], nd))
            return NamedSharding(mesh, P())
        if last == "state":  # rwkv (L, B, H, hd, hd)
            hdim = leaf.shape[-3]
            if batch_ok:
                return NamedSharding(mesh, _pad([dp, None, None, None], nd))
            if hdim % m == 0:
                return NamedSharding(mesh, _pad(["model", None, None], nd))
            return NamedSharding(mesh, P())
        if last == "encoder_out":  # (B, F, d)
            return NamedSharding(mesh, P(dp if batch_ok else None, None, None))
        if last in ("conv", "tmix_prev", "cmix_prev"):
            # (..., B, X, C): batch is third-from-last
            if batch_ok:
                return NamedSharding(
                    mesh, P(*([None] * (nd - 3) + [dp, None, None])))
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P())              # pos, idx

    return jax.tree_util.tree_map_with_path(one, cache_specs)


def token_sharding(mesh, token_spec, shape: InputShape):
    dp = data_axes(mesh)
    ok = shape.global_batch % axis_size(mesh, dp) == 0
    return NamedSharding(mesh, P(dp if ok else None, None))


def org_stack_sharding(mesh, ndim: int, block_size: int = 1,
                       shard_data: bool = False) -> NamedSharding:
    """Org-major stacked arrays (M, ...): leading dim split over the "org"
    axis.  Under one-to-one placement (``block_size == 1``) each
    organization's slice / params / fits live on their own device; under
    block placement a contiguous block of ``block_size`` orgs shares a
    device.  ``shard_data`` additionally splits the second (row) dim over
    the mesh's "data" axis for large local datasets."""
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    tail = [None] * (ndim - 1)
    if shard_data:
        if "data" not in mesh.axis_names:
            raise ValueError("shard_data=True needs a mesh with a 'data' "
                             f"axis, got axes {mesh.axis_names}")
        if ndim < 2:
            raise ValueError("shard_data=True needs a row dimension to "
                             f"shard, got ndim={ndim}")
        tail[0] = "data"
    return NamedSharding(mesh, P("org", *tail))


def org_replicated(mesh) -> NamedSharding:
    """Alice-side values (labels, ensemble carry) every org device holds."""
    return NamedSharding(mesh, P())


def attach(sds_tree, sharding_tree):
    """Return ShapeDtypeStructs carrying shardings (for .lower())."""
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        sds_tree, sharding_tree,
    )
