import os
if os.environ.get("REPRO_FORCE_DEVICES"):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count="
                               f"{os.environ['REPRO_FORCE_DEVICES']}")
"""Production training launcher: one GAL organization's local fit on the
production mesh.

On a real TPU slice this runs under the standard multi-host bootstrap
(jax.distributed.initialize from TPU env vars); on this CPU container use
REPRO_FORCE_DEVICES=8 with --mesh 2,4 for a faithful small-scale run.

Examples:
  # real run, smoke-scale, 8 fake devices
  REPRO_FORCE_DEVICES=8 PYTHONPATH=src python -m repro.launch.train \
      --arch llama3-8b --smoke --mesh 2,4 --steps 4 --batch 8 --seq 64
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="1,1",
                    help="data,model axis sizes (e.g. 16,16)")
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--loss-kind", default="lm_xent",
                    choices=("lm_xent", "gal_residual"))
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.launch import sharding as shd
    from repro.launch.mesh import make_device_mesh
    from repro.models import pspec as act_hints
    from repro.models import transformer as tfm
    from repro.train.steps import make_train_step
    from repro.data.tokens import make_token_stream, token_batches

    cfg = get_arch(args.arch, smoke=args.smoke)
    shape = tuple(int(x) for x in args.mesh.split(","))
    mesh = make_device_mesh(shape, ("data", "model"))
    act_hints.set_mesh(mesh)
    print(f"mesh={dict(mesh.shape)} devices={mesh.size} arch={cfg.arch}")

    key = jax.random.PRNGKey(0)
    params = tfm.init_params(key, cfg)
    p_sh = shd.params_shardings(cfg, mesh, params)
    params = jax.device_put(params, p_sh)
    step_fn, opt = make_train_step(cfg, args.loss_kind, lr=args.lr,
                                   microbatch=args.microbatch)
    opt_state = opt.init(params)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    rng_np = np.random.default_rng(0)
    stream = make_token_stream(rng_np, cfg.vocab, 100_000)
    batches = token_batches(stream, args.batch, args.seq, rng_np)
    with mesh:
        for step in range(args.steps):
            toks, labels = next(batches)
            batch = {"tokens": jnp.asarray(toks)}
            if args.loss_kind == "lm_xent":
                batch["labels"] = jnp.asarray(labels)
            else:
                from repro.core.gal_lm import compute_residual
                f0 = jnp.zeros((args.batch, args.seq, cfg.vocab))
                batch["residual"] = compute_residual(
                    jnp.asarray(labels), f0, use_kernel=False)
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            print(f"step {step}: loss={loss:.4f} ({time.time() - t0:.1f}s)",
                  flush=True)
    if args.checkpoint_dir:
        from repro.checkpoint import save_pytree
        save_pytree(f"{args.checkpoint_dir}/{cfg.arch}_final.npz", params)
        print(f"saved params to {args.checkpoint_dir}")


if __name__ == "__main__":
    main()
