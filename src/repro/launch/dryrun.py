import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers and compiles on the production mesh, and extract the
memory/cost/collective numbers the roofline analysis reads.

MUST be run as its own process (the XLA_FLAGS line above precedes any jax
import; jax locks the device count on first init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
      --out benchmarks/results/dryrun
"""
import argparse
import json
import time
from pathlib import Path

import jax

from repro.configs import SHAPES, arch_names, get_arch
from repro.launch.mesh import make_device_mesh, production_mesh_spec
from repro.launch import sharding as shd
from repro.launch.specs import (
    abstract_params, config_for_shape, input_specs, train_batch_specs,
    serve_specs,
)
from repro.roofline.analysis import (
    collective_bytes_from_hlo, dominant_term, model_flops, roofline_terms,
)
from repro.roofline.hlo_stats import analyze as hlo_analyze
from repro.train.steps import make_prefill_step, make_serve_step, make_train_step


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
                loss_kind: str = "gal_residual", flash: bool = False,
                remat: bool | None = None, attn_chunk: int | None = None,
                fsdp: bool = True, microbatch: int | None = None,
                remat_group: bool = False, keep_hlo: bool = False) -> dict:
    from dataclasses import replace
    shape = SHAPES[shape_name]
    cfg = config_for_shape(get_arch(arch), shape)
    # baseline memory policy: remat for training, chunked (flash-style)
    # attention for the long full-sequence shapes — required to fit HBM at
    # all (see EXPERIMENTS.md SS Dry-run)
    if remat is None:
        remat = shape.kind == "train"
    if attn_chunk is None:
        attn_chunk = 1024 if (shape.kind in ("train", "prefill")
                              and shape.seq_len >= 4096) else 0
    if microbatch is None:
        microbatch = 2 if shape.kind == "train" else 1
    cfg = replace(cfg, remat=remat, attn_chunk=attn_chunk,
                  remat_group=remat_group)
    mesh = make_device_mesh(*production_mesh_spec(multi_pod=multi_pod))
    n_chips = mesh.size
    from repro.models import pspec as act_hints
    act_hints.set_mesh(mesh)   # activation with_sharding_constraint policy
    aparams = abstract_params(cfg)
    p_sh = shd.params_shardings(cfg, mesh, aparams, fsdp=fsdp)
    params_in = shd.attach(aparams, p_sh)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            train_step, opt = make_train_step(cfg, loss_kind, flash=flash,
                                              microbatch=microbatch)
            aopt = jax.eval_shape(opt.init, aparams)
            o_sh = shd.opt_state_shardings(cfg, mesh, aopt, aparams)
            opt_in = shd.attach(aopt, o_sh)
            bspecs = train_batch_specs(cfg, shape, loss_kind)
            b_sh = shd.batch_shardings(cfg, mesh, bspecs)
            batch_in = shd.attach(bspecs, b_sh)
            lowered = jax.jit(train_step).lower(params_in, opt_in, batch_in)
        elif shape.kind == "prefill":
            prefill_step = make_prefill_step(cfg, flash=flash)
            bspecs = train_batch_specs(cfg, shape, loss_kind)
            b_sh = shd.batch_shardings(cfg, mesh, bspecs)
            batch_in = shd.attach(bspecs, b_sh)
            lowered = jax.jit(prefill_step).lower(params_in, batch_in)
        else:  # decode
            serve_step = make_serve_step(cfg)
            token_spec, cache_spec = serve_specs(cfg, shape)
            c_sh = shd.cache_shardings(cfg, mesh, cache_spec, shape)
            t_sh = shd.token_sharding(mesh, token_spec, shape)
            cache_in = shd.attach(cache_spec, c_sh)
            token_in = shd.attach(token_spec, t_sh)
            lowered = jax.jit(serve_step, donate_argnums=(1,)).lower(
                params_in, cache_in, token_in)   # cache donated: in/out alias
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)

    # loop-aware accounting: walk the call graph multiplying while-loop trip
    # counts (XLA's cost model counts scan bodies once)
    stats = hlo_analyze(hlo)
    terms = roofline_terms(cost, coll, n_chips, scan_correction=1.0)
    terms_corr = roofline_terms(
        {"flops": stats.flops, "bytes accessed": stats.bytes_accessed},
        stats.collectives, n_chips, scan_correction=1.0)

    mf = model_flops(cfg, shape, train=(shape.kind == "train"))
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips, "loss_kind": loss_kind,
        "flash": flash, "remat": remat, "microbatch": microbatch,
        "attn_chunk": attn_chunk, "fsdp": fsdp,
        "window": cfg.window,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes_per_device": (
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)),
        },
        "collectives_raw": coll,
        "collectives_loop_aware": stats.collectives,
        "roofline_raw": terms,
        "roofline": terms_corr,
        "dominant": dominant_term(terms_corr),
        "model_flops_global": mf,
        "useful_flops_ratio": (
            mf / (terms_corr["hlo_flops_per_chip"] * mesh.size)
            if terms_corr["hlo_flops_per_chip"] else None),
    }
    if keep_hlo:
        rec["hlo_len"] = len(hlo)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--loss-kind", default="gal_residual")
    ap.add_argument("--flash", action="store_true")
    ap.add_argument("--remat", type=int, default=None, choices=(0, 1))
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--remat-group", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    combos = []
    archs = arch_names() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch, shape, mp in combos:
        tag = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}"
        if args.loss_kind != "gal_residual":
            tag += f"__{args.loss_kind}"
        if args.flash:
            tag += "__flash"
        if args.remat is not None:
            tag += f"__remat{args.remat}"
        if args.attn_chunk is not None:
            tag += f"__chunk{args.attn_chunk}"
        if args.no_fsdp:
            tag += "__nofsdp"
        if args.microbatch is not None:
            tag += f"__mb{args.microbatch}"
        if args.remat_group:
            tag += "__rg"
        fp = outdir / f"{tag}.json"
        if fp.exists():
            print(f"[skip] {tag} (cached)")
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            rec = lower_combo(arch, shape, multi_pod=mp,
                              loss_kind=args.loss_kind, flash=args.flash,
                              remat=None if args.remat is None else bool(args.remat),
                              attn_chunk=args.attn_chunk,
                              fsdp=not args.no_fsdp,
                              microbatch=args.microbatch,
                              remat_group=args.remat_group)
            fp.write_text(json.dumps(rec, indent=1))
            r = rec["roofline"]
            print(f"  ok compile={rec['compile_s']}s "
                  f"peak/dev={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
                  f"compute={r['t_compute']*1e3:.2f}ms "
                  f"mem={r['t_memory']*1e3:.2f}ms "
                  f"coll={r['t_collective']*1e3:.2f}ms "
                  f"dom={rec['dominant']}", flush=True)
        except Exception as e:  # noqa: BLE001 — report and continue the sweep
            failures += 1
            print(f"  FAIL {tag}: {type(e).__name__}: {e}", flush=True)
            (outdir / f"{tag}.FAIL").write_text(f"{type(e).__name__}: {e}")
    print(f"done: {len(combos) - failures}/{len(combos)} lowered+compiled")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
