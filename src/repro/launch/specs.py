"""Abstract input specs (ShapeDtypeStruct stand-ins) for every
(architecture x input-shape) combination — shardable, no device allocation.

train/prefill shapes feed the GAL local residual-fit step; decode shapes feed
serve_step (ONE new token + seq_len cache). ``config_for_shape`` applies the
long-context policy: dense/full-attention archs run long_500k only through
the sliding-window variant (DESIGN.md SS3); SSM/hybrid run natively.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig, SHAPES
from repro.models import transformer as tfm

DEFAULT_WINDOW = 4096
WHISPER_WINDOW = 1024
TOPK_RESIDUAL = 64   # beyond-paper compressed transport


def config_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Long-context policy: give full-attention archs a sliding window for
    long_500k (the beyond-paper carve-in that lets all 40 pairs lower)."""
    if shape.name != "long_500k":
        return cfg
    if cfg.attention_free:
        return cfg                      # rwkv6: constant state, native
    if cfg.window is not None:
        return cfg                      # zamba2: already windowed shared attn
    win = WHISPER_WINDOW if cfg.is_encoder_decoder else DEFAULT_WINDOW
    return cfg.with_window(win)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: InputShape,
                      loss_kind: str = "gal_residual") -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    act_dt = cfg.dtype
    specs: Dict[str, Any] = {}
    s_text = s - cfg.num_patches if cfg.frontend == "vision" else s
    specs["tokens"] = _sds((b, s_text), jnp.int32)
    if loss_kind == "gal_residual":
        specs["residual"] = _sds((b, s_text, cfg.vocab), act_dt)
    elif loss_kind == "gal_residual_topk":
        specs["residual_idx"] = _sds((b, s_text, TOPK_RESIDUAL), jnp.int32)
        specs["residual_vals"] = _sds((b, s_text, TOPK_RESIDUAL), act_dt)
    elif loss_kind == "lm_xent":
        specs["labels"] = _sds((b, s_text), jnp.int32)
    if cfg.frontend == "vision":
        specs["patches"] = _sds((b, cfg.num_patches, cfg.d_model), act_dt)
    if cfg.is_encoder_decoder:
        specs["frames"] = _sds((b, cfg.num_frames, cfg.d_model), act_dt)
    return specs


def serve_specs(cfg: ModelConfig, shape: InputShape
                ) -> Tuple[Any, Dict[str, Any]]:
    """Returns (token_spec, cache_spec_tree) for decode shapes."""
    b, s = shape.global_batch, shape.seq_len
    token = _sds((b, 1), jnp.int32)
    enc_spec = None
    if cfg.is_encoder_decoder:
        enc_spec = _sds((b, cfg.num_frames, cfg.d_model), cfg.dtype)

    def build(enc):
        return tfm.init_cache(cfg, b, s, encoder_out=enc)

    if enc_spec is not None:
        cache = jax.eval_shape(build, enc_spec)
    else:
        cache = jax.eval_shape(lambda: build(None))
    return token, cache


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocating (132B-safe)."""
    return jax.eval_shape(
        lambda k: tfm.init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )


def input_specs(cfg: ModelConfig, shape_name: str,
                loss_kind: str = "gal_residual") -> Dict[str, Any]:
    """Unified entry: dict of abstract inputs for the step this shape lowers."""
    shape = SHAPES[shape_name]
    cfg = config_for_shape(cfg, shape)
    if shape.kind in ("train", "prefill"):
        return {"batch": train_batch_specs(cfg, shape, loss_kind)}
    token, cache = serve_specs(cfg, shape)
    return {"token": token, "cache": cache}
