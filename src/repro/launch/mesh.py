"""Mesh topology for the GAL runtime and the LM serving arc.

Defined as FUNCTIONS so importing this module never touches jax device state
(the dry-run sets XLA_FLAGS before any jax import; smoke tests see 1 device).

Two families of meshes live here:

* ``make_device_mesh`` — the generic dense-axis constructor used by the LM
  serving/training arc (data/model/pod axes).  ``production_mesh_spec``
  captures the TPU v5e target shapes that used to be hard-coded in the
  removed ``make_production_mesh``/``make_test_mesh`` seed constructors.
* ``make_org_mesh`` — the GAL protocol mesh: an "org" axis carrying the
  stacked organizations (optionally a block of several orgs per device) and
  an optional "data" axis sharding each org's N rows.
"""
from __future__ import annotations

import jax


def production_mesh_spec(*, multi_pod: bool = False) -> tuple:
    """(shape, axes) of the TPU v5e production target.

    Single pod: (16, 16) over ("data", "model") = 256 chips.
    Multi-pod:  (2, 16, 16) over ("pod", "data", "model") = 512 chips."""
    if multi_pod:
        return (2, 16, 16), ("pod", "data", "model")
    return (16, 16), ("data", "model")


def make_device_mesh(shape, axes):
    """Dense named device mesh over the first prod(shape) local devices.

    The one documented constructor for LM-arc meshes (serving, training,
    dry-run): pass ``production_mesh_spec()`` for the deployment target or a
    small shape like ``(2, 4)`` over ``("data", "model")`` for CPU sharding
    tests (requires >= prod(shape) local devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def org_mesh_eligible(m: int, data_shards: int = 1) -> bool:
    """True when an M-organization "org" mesh can be built on this host.

    Two placements are supported (d_org = device_count // data_shards is the
    size of the "org" axis):

    * one-to-one — ``M <= d_org`` and ``d_org % M == 0``: every org gets its
      own device (the paper's physically-separate compute sites).
    * block — ``M > d_org`` and ``M % d_org == 0``: the stacked org axis is
      block-sharded, a contiguous block of ``M // d_org`` orgs per device,
      so e.g. M=64 runs on 8 devices.

    ``data_shards`` > 1 additionally requires the device count to factor as
    d_org * data_shards.  Single-device hosts and M=1 are never eligible —
    the collectives would be pure overhead there."""
    d = len(jax.devices())
    if m <= 1 or d <= 1 or data_shards < 1 or d % data_shards != 0:
        return False
    d_org = d // data_shards
    if d_org < 1:
        return False
    if m <= d_org:
        return d_org % m == 0
    return m % d_org == 0


def org_block_size(m: int, data_shards: int = 1) -> int:
    """Orgs per device along the "org" axis (1 under one-to-one placement)."""
    d_org = len(jax.devices()) // data_shards
    return 1 if m <= d_org else m // d_org


def grouped_mesh_eligible(group_sizes) -> bool:
    """True when every planner group's org stack can shard its org axis
    across ALL local devices: multi-device host and the device count divides
    each group size. The grouped GAL engine then places one org-shard of
    every group per device — heterogeneous groups stay separate programs,
    each partitioned over the same "org" mesh (GSPMD), which is how a
    mixed-model org set on a matching device count maps onto the mesh."""
    d = len(jax.devices())
    return (d > 1 and bool(group_sizes)
            and all(s % d == 0 for s in group_sizes))


def make_org_mesh(m: int, data_shards: int = 1):
    """Mesh mapping organization blocks -> devices along an "org" axis.

    One-to-one placement uses the first M local devices, one organization
    each; block placement uses all d_org devices, a contiguous block of
    ``org_block_size(m)`` orgs per device.  With ``data_shards`` > 1 the
    mesh gains a second "data" axis that shards each org's N rows.  Callers
    gate on ``org_mesh_eligible``.  The org-sharded GAL engine places each
    org's vertical slice and per-round params along "org" and runs Alg. 1's
    residual broadcast / fitted-value gather as real collectives over this
    axis."""
    import numpy as np
    d_org = len(jax.devices()) // data_shards
    use = min(m, d_org)
    devs = np.asarray(jax.devices()[: use * data_shards])
    if data_shards == 1:
        return jax.sharding.Mesh(devs, ("org",))
    return jax.sharding.Mesh(devs.reshape(use, data_shards), ("org", "data"))


def data_axes(mesh) -> tuple:
    """The batch-parallel axes of a mesh (pod extends data across pods)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size
