"""Production mesh topology (TPU v5e target).

Defined as FUNCTIONS so importing this module never touches jax device state
(the dry-run sets XLA_FLAGS before any jax import; smoke tests see 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) over ("data", "model") = 256 chips.
    Multi-pod:  (2, 16, 16) over ("pod", "data", "model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU sharding tests (requires >= prod(shape) devices)."""
    return jax.make_mesh(shape, axes)


def org_mesh_eligible(m: int) -> bool:
    """True when an M-organization "org" mesh can be built: every org gets
    its own device (the paper's physically-separate compute sites), so M
    must divide the local device count. Single-device hosts and M=1 are
    never eligible — the collectives would be pure overhead there."""
    d = len(jax.devices())
    return 1 < m <= d and d % m == 0


def grouped_mesh_eligible(group_sizes) -> bool:
    """True when every planner group's org stack can shard its org axis
    across ALL local devices: multi-device host and the device count divides
    each group size. The grouped GAL engine then places one org-shard of
    every group per device — heterogeneous groups stay separate programs,
    each partitioned over the same "org" mesh (GSPMD), which is how a
    mixed-model org set on a matching device count maps onto the mesh."""
    d = len(jax.devices())
    return (d > 1 and bool(group_sizes)
            and all(s % d == 0 for s in group_sizes))


def make_org_mesh(m: int):
    """1-D mesh mapping organization index -> device along an "org" axis.

    Uses the first M local devices, one organization each; callers gate on
    ``org_mesh_eligible``. The org-sharded GAL engine places each org's
    vertical slice and per-round params on its device and runs Alg. 1's
    residual broadcast / fitted-value gather as real collectives over this
    axis."""
    import numpy as np
    return jax.sharding.Mesh(np.asarray(jax.devices()[:m]), ("org",))


def data_axes(mesh) -> tuple:
    """The batch-parallel axes of a mesh (pod extends data across pods)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size
