"""Step functions lowered by the launcher/dry-run and used by the examples.

Three training objectives:

  gal_residual_loss       — PAPER-FAITHFUL GAL local fit: the org's model
      regresses (ell_2) onto the dense broadcast pseudo-residual
      r in R^{B x S x V} (paper Alg. 1 step 3; Table 9 default ell_2).
  gal_residual_topk_loss  — BEYOND-PAPER transport: Alice broadcasts the
      residual compressed to top-K (values, indices) per token; the implicit
      off-support entries of r are 0, so the exact ell_2 objective is
          ||f||^2 - ||f_sel||^2 + ||f_sel - vals||^2
      computed without materializing the dense (B, S, V) target. Recorded
      separately in EXPERIMENTS.md SS Perf.
  lm_xent_loss            — Alice's own overarching L1 (next-token xent),
      used by the end-to-end example and the 'Alone/Joint' LM baselines.

serve_step is the paper's Prediction Stage at one org: a single new token
against a seq_len KV/state cache.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.optim.optimizers import adamw, apply_updates

AUX_COEF = 0.01  # MoE load-balance weight


def _forward(params, cfg: ModelConfig, batch, flash: bool):
    kwargs = {}
    if cfg.frontend == "vision":
        kwargs["patches"] = batch["patches"]
    if cfg.is_encoder_decoder:
        kwargs["frames"] = batch["frames"]
    logits, aux = tfm.apply(params, cfg, batch["tokens"], flash=flash, **kwargs)
    if cfg.frontend == "vision":
        logits = logits[:, cfg.num_patches:, :]   # loss on text positions
    return logits, aux


def gal_residual_loss(params, cfg: ModelConfig, batch, flash: bool = False):
    """ell_2 regression onto the dense broadcast pseudo-residual."""
    logits, aux = _forward(params, cfg, batch, flash)
    r = batch["residual"].astype(logits.dtype)
    diff = logits - r
    l2 = jnp.mean(jnp.square(diff).astype(jnp.float32))
    return l2 + AUX_COEF * aux, {"fit_l2": l2, "aux": aux}


def gal_residual_topk_loss(params, cfg: ModelConfig, batch,
                           flash: bool = False):
    """ell_2 onto a top-K compressed residual (exact when the true residual
    is supported on the K indices; the GAL residual y - softmax(F) is
    concentrated, making the truncation error tiny)."""
    logits, aux = _forward(params, cfg, batch, flash)
    idx = batch["residual_idx"]                      # (B, S, K) int32
    vals = batch["residual_vals"]
    vals = vals.astype(logits.dtype)
    f_sel = jnp.take_along_axis(logits, idx, axis=-1)
    total = (jnp.sum(jnp.square(logits), axis=-1, dtype=jnp.float32)
             - jnp.sum(jnp.square(f_sel), axis=-1, dtype=jnp.float32)
             + jnp.sum(jnp.square(f_sel - vals), axis=-1, dtype=jnp.float32))
    l2 = jnp.mean(total) / logits.shape[-1]
    return l2 + AUX_COEF * aux, {"fit_l2": l2, "aux": aux}


def lm_xent_loss(params, cfg: ModelConfig, batch, flash: bool = False):
    logits, aux = _forward(params, cfg, batch, flash)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss + AUX_COEF * aux, {"xent": loss, "aux": aux}


LOSS_FNS: Dict[str, Callable] = {
    "gal_residual": gal_residual_loss,
    "gal_residual_topk": gal_residual_topk_loss,
    "lm_xent": lm_xent_loss,
}


def make_train_step(cfg: ModelConfig, loss_kind: str = "gal_residual",
                    lr: float = 3e-4, weight_decay: float = 0.1,
                    flash: bool = False, microbatch: int = 1):
    """Returns (train_step, optimizer). train_step: (params, opt_state, batch)
    -> (params, opt_state, metrics).

    microbatch > 1 scans gradient-accumulation slices of the global batch
    (activation memory / microbatch; grads accumulate in f32)."""
    loss_fn = LOSS_FNS[loss_kind]
    opt = adamw(lr, weight_decay=weight_decay)

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, flash=flash), has_aux=True
        )(params)

    def accum_unrolled(params, batch):
        # STATIC slices: a lax.scan over microbatches dynamic-slices the
        # batch and trips an XLA SPMD verifier bug for the MoE archs
        mbs = batch[next(iter(batch))].shape[0] // microbatch
        g_acc = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        loss_sum = 0.0
        for i in range(microbatch):
            mb = jax.tree_util.tree_map(
                lambda x: jax.lax.slice_in_dim(x, i * mbs, (i + 1) * mbs,
                                               axis=0), batch)
            if i:
                # serialize: tie this slice to the previous accumulator so
                # the microbatch stashes never coexist in memory
                mb, g_acc = jax.lax.optimization_barrier((mb, g_acc))
            (loss, _), grads = grads_of(params, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
            loss_sum = loss_sum + loss
        return g_acc, loss_sum

    def accum_scan(params, batch):
        # default path: one live stash, best memory (non-MoE archs)
        def split(x):
            return x.reshape(microbatch, x.shape[0] // microbatch,
                             *x.shape[1:])

        micro = jax.tree_util.tree_map(split, batch)

        def accum(carry, mb):
            g_acc, loss_acc = carry
            (loss, _), grads = grads_of(params, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
            return (g_acc, loss_acc + loss), None

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g_acc, loss_sum), _ = jax.lax.scan(accum, (g0, 0.0), micro)
        return g_acc, loss_sum

    def train_step(params, opt_state, batch):
        if microbatch > 1:
            if cfg.is_moe:
                g_acc, loss_sum = accum_unrolled(params, batch)
            else:
                g_acc, loss_sum = accum_scan(params, batch)
            grads = jax.tree_util.tree_map(
                lambda g, p: (g / microbatch).astype(p.dtype), g_acc, params)
            loss = loss_sum / microbatch
            metrics = {"loss": loss}
        else:
            (loss, metrics), grads = grads_of(params, batch)
            metrics = dict(metrics, loss=loss)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, metrics

    return train_step, opt


def run_local_steps(train_step, params, opt_state, batch, steps: int):
    """Run ``steps`` optimizer steps over one fixed batch as a single
    lax.scan: a GAL organization's per-round local fit compiles to one device
    program instead of ``steps`` Python dispatches. ``train_step`` may be a
    raw step or a vmapped (org-stacked) one — the fused LM engine passes the
    latter. Returns (params, opt_state, stacked per-step metrics)."""

    def body(carry, _):
        p, s = carry
        p, s, metrics = train_step(p, s, batch)
        return (p, s), metrics

    (params, opt_state), metrics = jax.lax.scan(
        body, (params, opt_state), None, length=steps)
    return params, opt_state, metrics


def make_prefill_step(cfg: ModelConfig, flash: bool = False):
    """Inference prefill: full-sequence forward producing logits (scoring).
    Cache materialization is left to the serving layer (noted in DESIGN.md)."""

    def prefill_step(params, batch):
        logits, _ = _forward(params, cfg, batch, flash)
        return logits

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """Prediction-stage decode: ONE new token against a seq_len cache."""

    def serve_step(params, cache, token):
        logits, new_cache = tfm.decode_step(params, cfg, token, cache)
        return logits, new_cache

    return serve_step
