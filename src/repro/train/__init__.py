from repro.train.steps import (
    make_train_step, make_serve_step, make_prefill_step,
    gal_residual_loss, lm_xent_loss, gal_residual_topk_loss,
)
