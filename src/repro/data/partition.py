"""Vertical partitioning of features across organizations (paper Fig. 2).

  split_features       — disjoint column blocks of tabular data (UCI setting)
  split_image_patches  — grid patches of images (MNIST/CIFAR setting, Fig. 6):
                         M=2 -> left/right halves; M=4 -> 2x2; M=8 -> 2x4
  split_channels       — channel groups (modalities) of series/embeddings
                         (MIMIC setting; also the LM-scale GAL org split)
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def split_features(x: jnp.ndarray, m: int, rng: np.random.Generator | None = None
                   ) -> List[jnp.ndarray]:
    """Random (or contiguous) disjoint column blocks, sizes as equal as possible."""
    d = x.shape[-1]
    if m > d:
        raise ValueError(f"cannot split {d} features across {m} orgs")
    cols = np.arange(d) if rng is None else rng.permutation(d)
    blocks = np.array_split(cols, m)
    return [x[:, np.sort(b)] for b in blocks]


def _patch_grid(m: int):
    if m == 1:
        return 1, 1
    if m == 2:
        return 1, 2
    if m == 4:
        return 2, 2
    if m == 8:
        return 2, 4
    if m == 12:
        return 3, 4
    raise ValueError(f"unsupported patch count {m}")


def split_image_patches(x: jnp.ndarray, m: int) -> List[jnp.ndarray]:
    """x: (N, H, W, C) -> M patch tensors (N, H/gh, W/gw, C), row-major order
    so that for M=8 the centre patches are indices {1,2,5,6} (paper's
    1-indexed {2,3,6,7})."""
    gh, gw = _patch_grid(m)
    n, h, w, c = x.shape
    ph, pw = h // gh, w // gw
    patches = []
    for i in range(gh):
        for j in range(gw):
            patches.append(x[:, i * ph:(i + 1) * ph, j * pw:(j + 1) * pw, :])
    return patches


def split_channels(x: jnp.ndarray, sizes: Sequence[int]) -> List[jnp.ndarray]:
    """Split the last axis into groups of the given sizes (modalities)."""
    if sum(sizes) != x.shape[-1]:
        raise ValueError(f"sizes {sizes} do not sum to {x.shape[-1]}")
    out, start = [], 0
    for s in sizes:
        out.append(x[..., start:start + s])
        start += s
    return out


def flatten_for_tabular(patches: List[jnp.ndarray]) -> List[jnp.ndarray]:
    """Flatten image patches to (N, ph*pw*C) for tabular local models."""
    return [p.reshape(p.shape[0], -1) for p in patches]


def pad_and_stack(xs: Sequence[jnp.ndarray], pad_to: int | None = None
                  ) -> tuple:
    """Zero-pad vertical slices to a common width and stack them org-major:
    list of (N, d_m) -> ((M, N, d_max), [d_0..d_{M-1}]).

    The fused GAL engine vmaps ONE model over the stacked slices, which
    requires a homogeneous trailing dim. Zero columns are inert for the zoo
    models — ridge/RBF/stump solutions and MLP outputs are unchanged by
    constant-zero features — so per-org fits on the padded stack match fits
    on the raw slices (exactly for the closed-form models; up to the
    init-shape for randomly initialized ones).

    Higher-rank inputs (image patches, series) must already share a shape
    and are stacked unpadded.
    """
    dims = [int(x.shape[-1]) for x in xs]
    if xs[0].ndim != 2:
        if any(x.shape != xs[0].shape for x in xs):
            raise ValueError("non-tabular org inputs must share a shape; got "
                             f"{[x.shape for x in xs]}")
        return jnp.stack(xs), dims
    width = max(dims) if pad_to is None else pad_to
    if any(d > width for d in dims):
        raise ValueError(f"slice widths {dims} exceed pad width {width}")
    padded = [
        x if x.shape[-1] == width
        else jnp.pad(x, ((0, 0), (0, width - x.shape[-1])))
        for x in xs
    ]
    return jnp.stack(padded), dims


def stack_groups(xs: Sequence[jnp.ndarray],
                 index_groups: Sequence[Sequence[int]],
                 pad_tos: Sequence[int | None] | None = None,
                 mesh=None) -> tuple:
    """Per-group ``pad_and_stack``: partition ``xs`` by the planner's group
    index tuples and stack each group on its own pad geometry.

    The grouped GAL engine vmaps ONE model per group, so padding only has to
    be homogeneous *within* a group — a StumpBoost group and a KernelRidge
    group keep their own widths. Returns ``(stacks, dims, pads)``, all
    per-group lists; ``pad_tos`` pins each group's pad width (prediction
    stage must re-use the training geometry). With ``mesh`` given, each
    group's stack is placed org-sharded along the mesh's "org" axis
    (requires the device count to divide every group size).
    """
    stacks, dims, pads = [], [], []
    for gi, idx in enumerate(index_groups):
        pad_to = None if pad_tos is None else pad_tos[gi]
        stack, d = pad_and_stack([xs[i] for i in idx], pad_to=pad_to)
        if mesh is not None:
            from repro.launch.sharding import org_stack_sharding
            stack = jax.device_put(stack, org_stack_sharding(mesh, stack.ndim))
        stacks.append(stack)
        dims.append(d)
        pads.append(int(stack.shape[-1]) if stack.ndim == 3 else None)
    return stacks, dims, pads


def group_widths(xs: Sequence[jnp.ndarray],
                 index_groups: Sequence[Sequence[int]]) -> List[List[int]]:
    """Per-group trailing widths of the org slices, in the planner's group
    order — exactly the ``dims`` that ``stack_groups`` would compute,
    without building the stacks. The artifact lifecycle uses this as the
    resume-time geometry gate: a restored round-scan carry is only valid
    when the re-supplied slices match the fitted widths column for column
    (same pad targets, same per-org dims)."""
    return [[int(xs[i].shape[-1]) for i in idx] for idx in index_groups]


def unstack_groups(stacks: Sequence[jnp.ndarray],
                   index_groups: Sequence[Sequence[int]],
                   dims: Sequence[Sequence[int]] | None = None
                   ) -> List[jnp.ndarray]:
    """Inverse of ``stack_groups``: scatter each group's stacked rows back
    into original org order. With ``dims`` (the per-group true widths that
    ``stack_groups`` returned), tabular slices are trimmed back to their
    pre-pad width, so ``unstack_groups(*stack_groups(xs, idx)[:2], ...)``
    round-trips ``xs`` exactly; without ``dims`` the zero-padded rows are
    returned as-is (the layout ``predict_legacy`` needs after
    ``unpack_to_orgs``)."""
    n_orgs = sum(len(idx) for idx in index_groups)
    out: List[jnp.ndarray | None] = [None] * n_orgs
    for gi, idx in enumerate(index_groups):
        for j, i in enumerate(idx):
            x = stacks[gi][j]
            if dims is not None and x.ndim == 2:
                x = x[:, :int(dims[gi][j])]
            out[i] = x
    return out


def pad_and_stack_sharded(xs: Sequence[jnp.ndarray], mesh,
                          pad_to: int | None = None, block_size: int = 1,
                          shard_data: bool = False) -> tuple:
    """``pad_and_stack`` + placement: split the org-major stack over the
    mesh's "org" axis — one organization's padded slice per device under
    one-to-one placement, or a contiguous block of ``block_size`` orgs per
    device under block placement.  ``shard_data`` further splits each
    org's rows over the mesh's "data" axis.

    This is the data layout of the org-sharded GAL engine — org m's
    vertical slice physically lives on its block's device, mirroring the
    paper's decentralized sites; only the round collectives (residual
    broadcast, fitted-value gather) cross the device boundary."""
    from repro.launch.sharding import org_stack_sharding
    stack, dims = pad_and_stack(xs, pad_to=pad_to)
    orgs_held = mesh.shape["org"] * block_size
    if stack.shape[0] != orgs_held:
        raise ValueError(
            f"{stack.shape[0]} orgs cannot block-shard onto an org axis of "
            f"{mesh.shape['org']} devices holding {block_size} orgs each")
    sharding = org_stack_sharding(mesh, stack.ndim, block_size=block_size,
                                  shard_data=shard_data)
    return jax.device_put(stack, sharding), dims
