"""Synthetic stand-ins for the paper's datasets (offline container).

Each generator mirrors the *structure* the corresponding paper experiment
relies on (see DESIGN.md Sec. 1):

  make_regression       -> Diabetes / BostonHousing-like linear-ish regression
  make_blobs            -> the paper's 'Blob' (sklearn make_blobs analogue)
  make_classification   -> Wine / BreastCancer / QSAR-like margin tasks
  make_patch_images     -> MNIST/CIFAR-like images whose CENTRAL patches carry
                           the class signal (reproduces the Fig. 4c weight-
                           interpretability claim when split into patches)
  make_multimodal_series-> MIMIC-like 4-modality time series (MIMICL/MIMICM)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Dataset:
    x: jnp.ndarray            # features (or images (N,H,W,C), series (N,T,D))
    y: jnp.ndarray            # (N, K) one-hot or (N, 1) regression target
    task: str                 # "regression" | "classification" | "binary"
    name: str = "synthetic"


def _onehot(labels: np.ndarray, k: int) -> np.ndarray:
    return np.eye(k, dtype=np.float32)[labels]


def make_regression(rng: np.random.Generator, n: int = 442, d: int = 10,
                    noise: float = 0.3, nonlinear: float = 0.2) -> Dataset:
    x = rng.standard_normal((n, d)).astype(np.float32)
    w = rng.standard_normal((d, 1)).astype(np.float32)
    y = x @ w + nonlinear * np.sin(2.0 * x[:, :1]) * np.abs(x[:, 1:2])
    y = y + noise * rng.standard_normal((n, 1)).astype(np.float32)
    return Dataset(jnp.asarray(x), jnp.asarray(y.astype(np.float32)),
                   "regression", "regression")


def make_blobs(rng: np.random.Generator, n: int = 100, d: int = 10,
               k: int = 10, spread: float = 1.0) -> Dataset:
    centers = 4.0 * rng.standard_normal((k, d)).astype(np.float32)
    labels = rng.integers(0, k, size=n)
    x = centers[labels] + spread * rng.standard_normal((n, d)).astype(np.float32)
    return Dataset(jnp.asarray(x), jnp.asarray(_onehot(labels, k)),
                   "classification", "blob")


def make_classification(rng: np.random.Generator, n: int = 844, d: int = 41,
                        k: int = 2, informative: int | None = None,
                        margin: float = 1.0) -> Dataset:
    informative = informative or max(2, d // 2)
    x = rng.standard_normal((n, d)).astype(np.float32)
    w = rng.standard_normal((informative, k)).astype(np.float32)
    logits = margin * x[:, :informative] @ w
    logits += 0.5 * np.tanh(x[:, :informative] ** 2 @ np.abs(w))
    labels = np.argmax(
        logits + 0.5 * rng.standard_normal(logits.shape).astype(np.float32), axis=-1
    )
    return Dataset(jnp.asarray(x), jnp.asarray(_onehot(labels, k)),
                   "classification", "classification")


def make_patch_images(rng: np.random.Generator, n: int = 512, size: int = 16,
                      channels: int = 1, k: int = 10,
                      informative_center: bool = True) -> Dataset:
    """Images whose class signal is a per-class template concentrated in the
    CENTRE of the image; boundary pixels are noise. Splitting into patches
    gives the paper's MNIST/CIFAR patch setting where orgs 2,3,6,7 (centre)
    should earn larger assistance weights (Fig. 4c)."""
    templates = rng.standard_normal((k, size, size, channels)).astype(np.float32)
    if informative_center:
        yy, xx = np.meshgrid(np.arange(size), np.arange(size), indexing="ij")
        c = (size - 1) / 2.0
        mask = np.exp(-(((yy - c) ** 2 + (xx - c) ** 2) / (2 * (size / 5.0) ** 2)))
        templates *= mask[None, :, :, None].astype(np.float32) * 2.0
    labels = rng.integers(0, k, size=n)
    x = templates[labels] + 0.8 * rng.standard_normal(
        (n, size, size, channels)
    ).astype(np.float32)
    return Dataset(jnp.asarray(x), jnp.asarray(_onehot(labels, k)),
                   "classification", "patch_images")


def make_multimodal_series(rng: np.random.Generator, n: int = 1024,
                           t: int = 16, dims=(6, 4, 8, 4),
                           task: str = "regression") -> Dataset:
    """MIMIC-like: 4 modalities (microbiology, demographic, body, ICD) as
    channel groups of one (N, T, sum(dims)) series; target depends on all."""
    d = int(sum(dims))
    base = rng.standard_normal((n, 1, d)).astype(np.float32)
    drift = rng.standard_normal((n, t, d)).astype(np.float32).cumsum(axis=1) * 0.1
    x = base + drift
    w = rng.standard_normal((d, 1)).astype(np.float32)
    signal = (x.mean(axis=1) @ w) + 0.3 * np.abs(x[:, -1, :2]).sum(-1, keepdims=True)
    if task == "regression":
        y = signal + 0.3 * rng.standard_normal((n, 1)).astype(np.float32)
        return Dataset(jnp.asarray(x), jnp.asarray(y.astype(np.float32)),
                       "regression", "mimicl_like")
    # imbalanced binary (MIMICM-like): ~15% positive
    thr = np.quantile(signal, 0.85)
    y = (signal > thr).astype(np.float32)
    return Dataset(jnp.asarray(x), jnp.asarray(y), "binary", "mimicm_like")


def train_test_split(ds: Dataset, rng: np.random.Generator,
                     test_frac: float = 0.2) -> Tuple[Dataset, Dataset]:
    n = ds.x.shape[0]
    perm = rng.permutation(n)
    n_test = int(round(n * test_frac))
    te, tr = perm[:n_test], perm[n_test:]
    return (
        Dataset(ds.x[tr], ds.y[tr], ds.task, ds.name),
        Dataset(ds.x[te], ds.y[te], ds.task, ds.name + "_test"),
    )
