from repro.data.synthetic import (
    make_regression, make_blobs, make_classification, make_patch_images,
    make_multimodal_series, train_test_split, Dataset,
)
from repro.data.partition import split_features, split_image_patches, split_channels
from repro.data.tokens import make_token_stream, token_batches
