"""Synthetic token streams for the LM-scale drivers and smoke tests.

A small hidden Markov generator so the streams are learnable (loss decreases
during the end-to-end example run) rather than uniform noise.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def make_token_stream(rng: np.random.Generator, vocab: int, length: int,
                      n_states: int = 8) -> np.ndarray:
    """HMM over n_states latent states, each emitting a distinct vocab band."""
    trans = rng.dirichlet(np.ones(n_states) * 0.5, size=n_states)
    band = vocab // n_states
    state = int(rng.integers(n_states))
    out = np.empty(length, dtype=np.int32)
    states = np.empty(length, dtype=np.int32)
    for i in range(length):
        states[i] = state
        state = int(rng.choice(n_states, p=trans[state]))
    offsets = rng.integers(0, max(band, 1), size=length)
    out = (states * band + offsets).astype(np.int32) % vocab
    return out


def token_batches(stream: np.ndarray, batch: int, seq_len: int,
                  rng: np.random.Generator) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yield (tokens, labels) pairs of shape (batch, seq_len) forever."""
    n_positions = len(stream) - seq_len - 1
    while True:
        starts = rng.integers(0, n_positions, size=batch)
        toks = np.stack([stream[s:s + seq_len] for s in starts])
        labs = np.stack([stream[s + 1:s + seq_len + 1] for s in starts])
        yield toks, labs
