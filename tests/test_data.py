"""Data pipeline invariants (vertical partitioning is the paper's setting)."""
import numpy as np
import pytest
import jax.numpy as jnp
pytest.importorskip("hypothesis")  # optional dev dep; skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.data.partition import (
    flatten_for_tabular, split_channels, split_features, split_image_patches,
)
from repro.data.synthetic import (
    make_blobs, make_classification, make_multimodal_series,
    make_patch_images, make_regression, train_test_split,
)
from repro.data.tokens import make_token_stream, token_batches


@settings(max_examples=10, deadline=None)
@given(d=st.integers(4, 40), m=st.sampled_from([2, 4]))
def test_split_features_disjoint_and_complete(d, m):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, d)).astype(np.float32))
    parts = split_features(x, m)
    assert len(parts) == m
    assert sum(p.shape[-1] for p in parts) == d
    # contiguous split: concatenation reproduces x
    np.testing.assert_allclose(np.asarray(jnp.concatenate(parts, -1)),
                               np.asarray(x))


@pytest.mark.parametrize("m,grid", [(2, (1, 2)), (4, (2, 2)), (8, (2, 4)),
                                    (12, (3, 4))])
def test_split_image_patches_geometry(m, grid):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 24, 24, 3)).astype(np.float32))
    parts = split_image_patches(x, m)
    gh, gw = grid
    assert len(parts) == m
    assert parts[0].shape == (4, 24 // gh, 24 // gw, 3)
    flat = flatten_for_tabular(parts)
    assert flat[0].shape == (4, (24 // gh) * (24 // gw) * 3)


def test_split_channels_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 8, 22)).astype(np.float32))
    parts = split_channels(x, (6, 4, 8, 4))
    assert [p.shape[-1] for p in parts] == [6, 4, 8, 4]
    np.testing.assert_allclose(np.asarray(jnp.concatenate(parts, -1)),
                               np.asarray(x))
    with pytest.raises(ValueError):
        split_channels(x, (6, 4, 8, 5))


def test_generators_shapes_and_labels():
    rng = np.random.default_rng(0)
    ds = make_regression(rng, n=50, d=7)
    assert ds.x.shape == (50, 7) and ds.y.shape == (50, 1)
    ds = make_blobs(rng, n=40, d=5, k=3)
    assert ds.y.shape == (40, 3)
    np.testing.assert_allclose(np.asarray(ds.y.sum(-1)), 1.0)
    ds = make_classification(rng, n=60, d=9, k=2)
    assert set(np.asarray(ds.y.argmax(-1))) <= {0, 1}
    ds = make_patch_images(rng, n=10, size=8, k=4)
    assert ds.x.shape == (10, 8, 8, 1)
    ds = make_multimodal_series(rng, n=16, t=5, task="binary")
    assert ds.x.shape == (16, 5, 22)
    assert float(ds.y.mean()) < 0.5     # imbalanced (MIMICM-like)


def test_train_test_split_disjoint():
    rng = np.random.default_rng(0)
    ds = make_regression(rng, n=100, d=4)
    tr, te = train_test_split(ds, rng, test_frac=0.25)
    assert tr.x.shape[0] == 75 and te.x.shape[0] == 25


def test_token_stream_learnable_structure():
    rng = np.random.default_rng(0)
    stream = make_token_stream(rng, vocab=64, length=5000)
    assert stream.min() >= 0 and stream.max() < 64
    toks, labs = next(token_batches(stream, 4, 16, rng))
    assert toks.shape == labs.shape == (4, 16)
    # labels are next tokens
    np.testing.assert_array_equal(toks[:, 1:], labs[:, :-1])
