"""End-to-end behaviour of the GAL protocol (paper Alg. 1 + Sec. 4 claims)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import al, boosting, gal
from repro.core.gal import GALConfig
from repro.core.losses import get_loss
from repro.core.organizations import make_orgs
from repro.data.partition import split_features
from repro.data.synthetic import make_blobs, make_regression, train_test_split
from repro.metrics.metrics import accuracy, mad
from repro.models.zoo import Linear, MLP


def _regression_setting(rng_np, m=4):
    ds = make_regression(rng_np, n=400, d=12)
    tr, te = train_test_split(ds, rng_np)
    return split_features(tr.x, m), tr.y, split_features(te.x, m), te.y


def test_gal_decreases_train_loss_monotonically(rng_np, key):
    """Every GAL round decreases the overarching loss (paper Sec. 2:
    'Each round of updates will decrease the loss')."""
    xs, y, _, _ = _regression_setting(rng_np)
    loss = get_loss("mse")
    res = gal.fit(key, make_orgs(xs, Linear()), y, loss, GALConfig(rounds=5))
    tl = res.history["train_loss"]
    assert all(tl[i + 1] <= tl[i] + 1e-6 for i in range(len(tl) - 1)), tl


def test_gal_near_oracle_beats_alone(rng_np, key):
    """GAL ~ Joint oracle and >> Alone (paper Tables 1-3)."""
    xs, y, xs_te, y_te = _regression_setting(rng_np)
    loss = get_loss("mse")
    cfg = GALConfig(rounds=6)
    res = gal.fit(key, make_orgs(xs, Linear()), y, loss, cfg,
                  eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
    joint = boosting.fit_joint(key, xs, y, loss, Linear(), cfg,
                               eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
    alone = boosting.fit_alone(key, xs[0], y, loss, Linear(), cfg,
                               eval_sets={"test": ([xs_te[0]], y_te)},
                               metric_fn=mad)
    gal_mad = res.history["test_metric"][-1]
    joint_mad = joint.history["test_metric"][-1]
    alone_mad = alone.history["test_metric"][-1]
    assert gal_mad < alone_mad * 0.7, (gal_mad, alone_mad)
    assert gal_mad < joint_mad * 1.5, (gal_mad, joint_mad)


def test_gal_beats_al_with_same_budget(rng_np, key):
    """GAL converges better AND faster than sequential AL (paper Sec. 4.3)."""
    xs, y, xs_te, y_te = _regression_setting(rng_np)
    loss = get_loss("mse")
    res = gal.fit(key, make_orgs(xs, Linear()), y, loss, GALConfig(rounds=4),
                  eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
    alres = al.fit(key, make_orgs(xs, Linear()), y, loss, total_steps=4,
                   eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
    assert res.history["test_metric"][-1] < alres.history["test_metric"][-1]


def test_gal_classification_blobs(rng_np, key):
    ds = make_blobs(rng_np, n=150, d=10, k=5)
    tr, te = train_test_split(ds, rng_np)
    xs, xs_te = split_features(tr.x, 4), split_features(te.x, 4)
    loss = get_loss("xent")
    res = gal.fit(key, make_orgs(xs, Linear()), y=tr.y, loss=loss,
                  config=GALConfig(rounds=5),
                  eval_sets={"test": (xs_te, te.y)}, metric_fn=accuracy)
    assert res.history["test_metric"][-1] >= 90.0


def test_predict_matches_streaming_eval(rng_np, key):
    """Prediction-stage assembly == per-round streaming eval (Alg. 1)."""
    xs, y, xs_te, y_te = _regression_setting(rng_np)
    loss = get_loss("mse")
    res = gal.fit(key, make_orgs(xs, Linear()), y, loss, GALConfig(rounds=4),
                  eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
    pred = res.predict(xs_te)
    np.testing.assert_allclose(float(mad(y_te, pred)),
                               res.history["test_metric"][-1], rtol=1e-5)


def test_joint_reduces_to_gradient_boosting(rng_np, key):
    """With M=1, weights are trivially 1 and GAL == gradient boosting:
    the direction is exactly the single org's fitted residual."""
    xs, y, _, _ = _regression_setting(rng_np, m=1)
    loss = get_loss("mse")
    res = gal.fit(key, make_orgs(xs, Linear()), y, loss, GALConfig(rounds=3))
    for w in res.weights:
        np.testing.assert_allclose(np.asarray(w), [1.0], atol=1e-6)


def test_eta_line_search_beats_constant(rng_np, key):
    """Line-searched eta converges faster than eta=1 (paper Fig. 4a/d)."""
    xs, y, _, _ = _regression_setting(rng_np)
    loss = get_loss("mse")
    ls = gal.fit(key, make_orgs(xs, Linear()), y, loss,
                 GALConfig(rounds=3, eta_method="lbfgs"))
    const = gal.fit(key, make_orgs(xs, Linear()), y, loss,
                    GALConfig(rounds=3, eta_method="constant", eta0=1.0))
    assert ls.history["train_loss"][-1] <= const.history["train_loss"][-1] + 1e-6


def test_eta_stop_threshold(rng_np, key):
    xs, y, _, _ = _regression_setting(rng_np)
    loss = get_loss("mse")
    # mechanism test: with a threshold above the typical line-search value,
    # assistance stops after the first round (paper Sec. 4.5 stopping rule)
    res = gal.fit(key, make_orgs(xs, Linear()), y, loss,
                  GALConfig(rounds=30, eta_stop_threshold=10.0))
    assert res.rounds == 1


def test_model_autonomy_mixed_models(rng_np, key):
    """GB-SVM style mixed local models work (paper Table 1, model autonomy)."""
    from repro.models.zoo import KernelRidge, StumpBoost
    xs, y, xs_te, y_te = _regression_setting(rng_np)
    models = [Linear(), StumpBoost(n_stumps=30), KernelRidge(), MLP((32,))]
    res = gal.fit(key, make_orgs(xs, models), y, get_loss("mse"),
                  GALConfig(rounds=4),
                  eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
    assert res.history["train_loss"][-1] < res.history["train_loss"][0]
