"""The cross-engine conformance matrix: the suite that proves the Python
reference loop is now a pure test oracle.

One parametrized matrix of engine x scenario cells — every scenario the
paper exercises (homogeneous, model-autonomy hetero mix, noisy orgs, Deep
Model Sharing, custom autodiff-residual local losses, early stopping, and
the DMS + custom-loss mix) against every engine that can run it (scan for
single noiseless fresh-fit groups, grouped for everything compilable,
shard when an org mesh exists). Each cell asserts the FULL contract
against the Python oracle, draw for draw:

  * etas and assistance weights per round,
  * every history column — losses, device-side metrics, the communication
    ledger and the model-memory ledger (exact ints), with identical column
    sets on both engines,
  * ``predict(xs, rounds=t)`` for every prefix t (the Fig. 4 replay).

If a compiled engine drifts from the reference on any recorded quantity,
this file is where it fails.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import gal
from repro.core.gal import GALConfig
from repro.core.losses import get_loss, lq_loss
from repro.core.organizations import make_orgs
from repro.core.plan import plan_orgs
from repro.data.partition import split_features
from repro.data.synthetic import make_regression, train_test_split
from repro.launch.mesh import org_mesh_eligible
from repro.models.zoo import KernelRidge, Linear, MLP, StumpBoost

M = 4
ROUNDS = 3


def _pseudo_huber(r, f):
    """A differentiable local loss with NO ell_q exponent: compiles through
    the autodiff-residual path, not the closed forms."""
    return jnp.mean(jnp.sqrt(1.0 + jnp.square(r - f)) - 1.0)


def _data():
    rng_np = np.random.default_rng(7)
    ds = make_regression(rng_np, n=160, d=12)
    tr, te = train_test_split(ds, rng_np)
    return (split_features(tr.x, M), tr.y,
            split_features(te.x, M), te.y)


# scenario -> (orgs factory, config kwargs, engines beyond python/grouped)
SCENARIOS = {
    "homogeneous": dict(
        orgs=lambda xs: make_orgs(xs, Linear()),
        cfg={}, extra_engines=("scan", "shard")),
    "hetero": dict(
        orgs=lambda xs: make_orgs(
            xs, [StumpBoost(n_stumps=8) if i % 2 == 0 else KernelRidge()
                 for i in range(M)]),
        cfg={}, extra_engines=()),
    "noisy": dict(
        orgs=lambda xs: make_orgs(xs, Linear(),
                                  noise_sigmas=[0.0, 1.0, 0.0, 1.0]),
        cfg={}, extra_engines=()),
    "dms": dict(
        orgs=lambda xs: make_orgs(xs, MLP((8,), epochs=5), dms=True),
        cfg={}, extra_engines=()),
    "custom_loss": dict(
        orgs=lambda xs: make_orgs(xs, Linear(epochs=25),
                                  local_losses=_pseudo_huber),
        cfg={}, extra_engines=("scan", "shard")),
    "early_stop": dict(
        orgs=lambda xs: make_orgs(xs, Linear()),
        cfg={"rounds": 8, "eta_stop_threshold": 10.0},
        extra_engines=("scan", "shard")),
    "dms_custom_mix": dict(
        orgs=lambda xs: make_orgs(
            xs,
            [MLP((8,), epochs=5), MLP((8,), epochs=5),
             Linear(epochs=25), Linear(epochs=25)],
            local_losses=[lq_loss(2.0), lq_loss(2.0),
                          _pseudo_huber, _pseudo_huber],
            dms=[True, True, False, False]),
        cfg={}, extra_engines=()),
}

_CELLS = [(s, e) for s, spec in SCENARIOS.items()
          for e in ("grouped",) + spec["extra_engines"]]

_ORACLE_CACHE = {}


def _fit(scenario, engine, key):
    xs, y, xs_te, y_te = _data()
    spec = SCENARIOS[scenario]
    cfg = GALConfig(**{"rounds": ROUNDS, "engine": engine, **spec["cfg"]})
    return gal.fit(key, spec["orgs"](xs), y, get_loss("mse"), cfg,
                   eval_sets={"test": (xs_te, y_te)}, metrics=("mad",))


def _oracle(scenario, key):
    if scenario not in _ORACLE_CACHE:
        _ORACLE_CACHE[scenario] = _fit(scenario, "python", key)
    return _ORACLE_CACHE[scenario]


@pytest.mark.parametrize("scenario,engine", _CELLS,
                         ids=[f"{s}-{e}" for s, e in _CELLS])
def test_engine_matches_python_oracle(rng_np, key, scenario, engine):
    if engine == "shard" and not org_mesh_eligible(M):
        pytest.skip(f"no org mesh for {M} orgs on "
                    f"{len(jnp.zeros(1).devices())} device(s) "
                    f"(run under REPRO_FORCE_DEVICES={M})")
    res_py = _oracle(scenario, key)
    res = _fit(scenario, engine, key)
    assert res.engine == engine
    if res.plan is not None:
        assert res.plan.compiled and res.plan.reason is None

    # etas + assistance weights, draw for draw
    assert res.rounds == res_py.rounds
    np.testing.assert_allclose(res.etas, res_py.etas, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.stack(res.weights),
                               np.stack(res_py.weights), atol=1e-3)

    # the FULL history: same column set, every column equal. Ledger
    # columns (comm_*, model_memories) are exact Python ints.
    assert set(res.history) == set(res_py.history)
    for col in res_py.history:
        if col.startswith("comm_") or col == "model_memories":
            assert res.history[col] == res_py.history[col], col
            assert all(isinstance(v, int) for v in res.history[col]), col
        else:
            np.testing.assert_allclose(res.history[col],
                                       res_py.history[col],
                                       rtol=1e-3, atol=1e-3, err_msg=col)

    # prediction-stage replay at every round prefix (Fig. 4 protocol)
    xs, _, xs_te, _ = _data()
    for t in range(res_py.rounds + 1):
        np.testing.assert_allclose(
            np.asarray(res.predict(xs_te, rounds=t)),
            np.asarray(res_py.predict(xs_te, rounds=t)),
            rtol=1e-3, atol=1e-3,
            err_msg=f"{scenario}/{engine} predict(rounds={t})")


def test_dms_custom_mix_compiles_without_reason(rng_np, key):
    """The acceptance scenario: a DMS + custom-loss org mix plans into two
    compiled groups with NO fallback reason and runs on engine='grouped'."""
    xs, _, _, _ = _data()
    plan = plan_orgs(SCENARIOS["dms_custom_mix"]["orgs"](xs))
    assert plan.compiled and plan.reason is None
    assert plan.n_groups == 2 and plan.has_dms
    assert plan.groups[0].dms and not plan.groups[1].dms


def test_dms_with_sharp_loss_stays_finite_and_matches_oracle(rng_np, key):
    """Regression: a custom DMS loss with an unbounded derivative at
    r == f (sqrt(|r - f|)) must NOT NaN the grouped engine. The masked
    head slots sit exactly at that point (zero heads on zero residuals);
    without the double-where in the traced objective, 0 * inf cotangents
    poison the shared extractor and every recorded quantity."""
    def sharp(r, f):
        return jnp.mean(jnp.sqrt(jnp.abs(r - f)))

    xs, y, xs_te, _ = _data()
    orgs = lambda: make_orgs(xs, MLP((8,), epochs=5),  # noqa: E731
                             local_losses=sharp, dms=True)
    res_py = gal.fit(key, orgs(), y, get_loss("mse"),
                     GALConfig(rounds=2, engine="python"))
    res_gr = gal.fit(key, orgs(), y, get_loss("mse"),
                     GALConfig(rounds=2, engine="grouped"))
    assert np.isfinite(res_gr.history["train_loss"]).all()
    assert np.isfinite(res_py.history["train_loss"]).all()
    # looser tolerance than the matrix: sqrt's 1/sqrt gradient is unbounded
    # wherever f approaches r on LIVE slots too, so fp association noise
    # between the list-pytree and stacked-buffer Adam refits is amplified;
    # the regression target is finiteness + agreement, not bit parity
    np.testing.assert_allclose(res_gr.etas, res_py.etas,
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(res_gr.predict(xs_te)),
                               np.asarray(res_py.predict(xs_te)),
                               rtol=2e-2, atol=2e-2)


def test_scan_and_grouped_bitwise_identical_cells(rng_np, key):
    """scan is a veneer over grouped: on a homogeneous scenario the two
    compiled cells must agree bit for bit, not just to tolerance."""
    res_sc = _fit("homogeneous", "scan", key)
    res_gr = _fit("homogeneous", "grouped", key)
    np.testing.assert_array_equal(res_sc.etas, res_gr.etas)
    np.testing.assert_array_equal(res_sc.history["train_loss"],
                                  res_gr.history["train_loss"])


def test_early_stop_trims_every_column_identically(rng_np, key):
    """Early stopping must trim losses, metrics, and all three ledgers to
    the same executed-round count on every engine."""
    res_py = _oracle("early_stop", key)
    res_gr = _fit("early_stop", "grouped", key)
    for res in (res_py, res_gr):
        t = res.rounds
        assert t < 8                      # the threshold actually fired
        assert len(res.history["train_loss"]) == t + 1
        assert len(res.history["test_loss"]) == t + 1
        assert len(res.history["test_mad"]) == t + 1
        assert len(res.history["comm_broadcast_bytes"]) == t
        assert len(res.history["model_memories"]) == t
