"""Optimizer / schedule correctness."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
pytest.importorskip("hypothesis")  # optional dev dep; skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.optim.optimizers import adam, adamw, apply_updates, sgd
from repro.optim.schedules import (
    constant, cosine_decay, gal_theory_rate, linear_warmup_cosine,
)


def _minimize(opt, steps=300):
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = jnp.zeros(3)
    state = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p - target))

    for _ in range(steps):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    return float(loss(params))


@pytest.mark.parametrize("make", [
    lambda: sgd(0.1), lambda: sgd(0.05, momentum=0.9),
    lambda: adam(0.05), lambda: adamw(0.05, weight_decay=0.0),
])
def test_optimizers_converge_on_quadratic(make):
    assert _minimize(make()) < 1e-2


def test_adamw_decoupled_decay_shrinks_params():
    opt = adamw(0.01, weight_decay=0.5)
    params = jnp.ones(4)
    state = opt.init(params)
    for _ in range(50):
        upd, state = opt.update(jnp.zeros(4), state, params)
        params = apply_updates(params, upd)
    assert float(jnp.max(jnp.abs(params))) < 1.0


def test_schedules_shapes():
    s = cosine_decay(1.0, 100)
    assert float(s(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(s(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-5)
    w = linear_warmup_cosine(1.0, 10, 100)
    assert float(w(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(w(jnp.asarray(10))) == pytest.approx(1.0, abs=0.05)


@settings(max_examples=10, deadline=None)
@given(t_max=st.integers(10, 2000))
def test_gal_theory_rate_satisfies_thm1(t_max):
    """a_t = a0/(t+1): sum diverges, sum of squares converges (Thm 1 A2)."""
    ts = np.arange(t_max)
    a = np.asarray([float(gal_theory_rate(t)) for t in ts[:50]])
    assert np.all(a > 0) and np.all(np.diff(a) < 0)
    # partial sums: harmonic grows, squares bounded by pi^2/6
    assert np.sum(1.0 / (ts + 1)) > np.log(t_max) * 0.9
    assert np.sum(1.0 / (ts + 1.0) ** 2) < 1.6449342
