"""Property-based invariants of the org execution planner and the group
stacking round trip (hypothesis; skips cleanly when the optional dev dep is
absent, like the other property suites)."""
import numpy as np
import pytest
import jax.numpy as jnp
pytest.importorskip("hypothesis")  # optional dev dep; skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core.losses import lq_loss
from repro.core.organizations import make_orgs
from repro.core.plan import _group_key, plan_orgs
from repro.data.partition import stack_groups, unstack_groups
from repro.models.zoo import KernelRidge, Linear, MLP, StumpBoost

N_ROWS = 24


def _custom_loss(r, f):
    return jnp.mean(jnp.sqrt(1.0 + jnp.square(r - f)) - 1.0)


# per-org spec: (model id, loss id, noise on, dms, slice width)
_ORG_SPEC = st.tuples(
    st.sampled_from(["linear", "stumps", "kernel", "mlp"]),
    st.sampled_from(["q1", "q2", "q4", "custom"]),
    st.booleans(),
    st.booleans(),
    st.integers(2, 5),
)

_MODELS = {"linear": Linear(), "stumps": StumpBoost(n_stumps=4),
           "kernel": KernelRidge(), "mlp": MLP((4,), epochs=2)}
_LOSSES = {"q1": lq_loss(1.0), "q2": lq_loss(2.0), "q4": lq_loss(4.0),
           "custom": _custom_loss}


def _orgs_from_specs(specs, seed):
    rng = np.random.default_rng(seed)
    xs = [jnp.asarray(rng.standard_normal((N_ROWS, w)).astype(np.float32))
          for (_, _, _, _, w) in specs]
    return make_orgs(
        xs,
        [_MODELS[m] for (m, _, _, _, _) in specs],
        local_losses=[_LOSSES[q] for (_, q, _, _, _) in specs],
        noise_sigmas=[0.5 if noisy else 0.0
                      for (_, _, noisy, _, _) in specs],
        # DMS only for the model that has the extractor/head interface
        dms=[d and m == "mlp" for (m, _, _, d, _) in specs],
    )


@settings(max_examples=40, deadline=None)
@given(specs=st.lists(_ORG_SPEC, min_size=1, max_size=7),
       seed=st.integers(0, 99))
def test_groups_partition_the_index_set_exactly(specs, seed):
    """Every org appears in exactly one group, groups preserve org_ids,
    and the permutation/inverse pair is a bijection."""
    orgs = _orgs_from_specs(specs, seed)
    plan = plan_orgs(orgs)
    all_indices = sorted(i for g in plan.groups for i in g.indices)
    assert all_indices == list(range(len(orgs)))
    for g in plan.groups:
        assert g.org_ids == tuple(orgs[i].index for i in g.indices)
    perm = plan.permutation
    inv = plan.inverse_permutation
    assert sorted(perm) == list(range(len(orgs)))
    assert tuple(perm[inv[i]] for i in range(len(orgs))) == \
        tuple(range(len(orgs)))


@settings(max_examples=40, deadline=None)
@given(specs=st.lists(_ORG_SPEC, min_size=1, max_size=7),
       seed=st.integers(0, 99))
def test_every_group_is_key_homogeneous(specs, seed):
    """Within a group, every org shares the grouping key — model config,
    local loss, noise sigma, DMS flag (and width where it matters); across
    groups the keys differ (no two groups could have been merged)."""
    orgs = _orgs_from_specs(specs, seed)
    plan = plan_orgs(orgs)
    group_keys = []
    for g in plan.groups:
        keys = {repr(_group_key(orgs[i])) for i in g.indices}
        assert len(keys) == 1, f"group {g.describe()} mixes keys: {keys}"
        group_keys.append(keys.pop())
    assert len(set(group_keys)) == len(group_keys), \
        "two groups share a key (should have been merged)"


@settings(max_examples=40, deadline=None)
@given(specs=st.lists(_ORG_SPEC, min_size=1, max_size=7),
       seed=st.integers(0, 99))
def test_unstack_groups_inverts_stack_groups(specs, seed):
    """The engine's scatter (``unstack_groups``) is the exact inverse of
    the planner-driven gather (``stack_groups``): slices come back in org
    order at their true widths, bit for bit."""
    orgs = _orgs_from_specs(specs, seed)
    plan = plan_orgs(orgs)
    xs = [org.x_train for org in orgs]
    index_groups = [g.indices for g in plan.groups]
    stacks, dims, pads = stack_groups(xs, index_groups)
    back = unstack_groups(stacks, index_groups, dims)
    for i, (orig, rec) in enumerate(zip(xs, back)):
        np.testing.assert_array_equal(np.asarray(orig), np.asarray(rec),
                                      err_msg=f"org {i}")


@settings(max_examples=25, deadline=None)
@given(specs=st.lists(_ORG_SPEC, min_size=1, max_size=7),
       seed=st.integers(0, 99))
def test_compiled_verdict_matches_group_flags(specs, seed):
    """These random mixes contain only traceable models/losses, so the plan
    always compiles; has_dms/noisy reflect the org flags; 'homogeneous'
    holds iff there is one noiseless fresh-fit group."""
    orgs = _orgs_from_specs(specs, seed)
    plan = plan_orgs(orgs)
    assert plan.compiled, plan.reason
    assert plan.has_dms == any(org.dms for org in orgs)
    assert plan.noisy == any(org.noise_sigma > 0 for org in orgs)
    assert plan.homogeneous == (plan.n_groups == 1 and not plan.noisy
                                and not plan.has_dms)
