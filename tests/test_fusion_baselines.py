"""Centralized fusion baselines (paper's 'Interm' and 'Late' upper bounds)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import fusion, gal
from repro.core.gal import GALConfig
from repro.core.losses import get_loss
from repro.core.organizations import make_orgs
from repro.data.partition import split_features
from repro.data.synthetic import make_blobs, make_regression, train_test_split
from repro.metrics.metrics import accuracy, mad
from repro.models.zoo import MLP, Linear


def test_late_fusion_trains_and_predicts(rng_np, key):
    ds = make_regression(rng_np, n=300, d=12)
    tr, te = train_test_split(ds, rng_np)
    xs, xs_te = split_features(tr.x, 4), split_features(te.x, 4)
    res = fusion.fit_late(key, xs, tr.y, get_loss("mse"), Linear(),
                          epochs=300, lr=3e-2)
    pred = res.predict(xs_te)
    assert pred.shape == te.y.shape
    # centralized late fusion should beat a single-org linear fit
    assert float(mad(te.y, pred)) < float(mad(te.y, jnp.zeros_like(te.y)))


def test_interm_fusion_deep_models(rng_np, key):
    ds = make_blobs(rng_np, n=160, d=12, k=4)
    tr, te = train_test_split(ds, rng_np)
    xs, xs_te = split_features(tr.x, 4), split_features(te.x, 4)
    res = fusion.fit_interm(key, xs, tr.y, get_loss("xent"),
                            MLP((16,)), epochs=300, lr=1e-2)
    pred = res.predict(xs_te)
    acc = float(accuracy(te.y, pred))
    assert acc > 50.0, acc


def test_gal_close_to_late_fusion(rng_np, key):
    """Paper Sec 4.1: GAL performs close to the centralized baselines."""
    ds = make_regression(rng_np, n=400, d=12)
    tr, te = train_test_split(ds, rng_np)
    xs, xs_te = split_features(tr.x, 4), split_features(te.x, 4)
    loss = get_loss("mse")
    late = fusion.fit_late(key, xs, tr.y, loss, Linear(), epochs=400, lr=3e-2)
    late_mad = float(mad(te.y, late.predict(xs_te)))
    res = gal.fit(key, make_orgs(xs, Linear()), tr.y, loss, GALConfig(rounds=6),
                  eval_sets={"test": (xs_te, te.y)}, metric_fn=mad)
    gal_mad = res.history["test_metric"][-1]
    assert gal_mad < late_mad * 1.5, (gal_mad, late_mad)
