"""Unit tests of the org execution planner (repro.core.plan).

The planner is the single eligibility oracle of gal.fit's engine dispatch:
it must (a) partition compilable org sets into homogeneous groups keyed by
(model signature, ell_q, noise sigma, slice rank/width), preserving
first-occurrence order and org membership, and (b) name a human-readable
reason whenever the compiled engines cannot run at all.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.losses import lq_loss
from repro.core.organizations import make_orgs
from repro.core.plan import plan_lm_orgs, plan_orgs
from repro.data.partition import split_features
from repro.models.zoo import KernelRidge, Linear, MLP, StumpBoost


def _xs(rng_np, n=64, d=12, m=4):
    x = jnp.asarray(rng_np.standard_normal((n, d)).astype(np.float32))
    return split_features(x, m)


def test_homogeneous_orgs_one_group(rng_np):
    plan = plan_orgs(make_orgs(_xs(rng_np), Linear()))
    assert plan.compiled and plan.homogeneous
    assert plan.n_groups == 1 and plan.groups[0].size == 4
    assert plan.groups[0].indices == (0, 1, 2, 3)
    assert "Linear x4" in plan.describe()


def test_mixed_models_group_by_signature(rng_np):
    models = [StumpBoost(), KernelRidge(), StumpBoost(), KernelRidge()]
    plan = plan_orgs(make_orgs(_xs(rng_np), models))
    assert plan.compiled and not plan.homogeneous
    assert plan.n_groups == 2
    assert plan.groups[0].indices == (0, 2)      # first-occurrence order
    assert plan.groups[1].indices == (1, 3)
    assert plan.permutation == (0, 2, 1, 3)
    assert plan.inverse_permutation == (0, 2, 1, 3)


def test_differing_model_config_splits_groups(rng_np):
    models = [StumpBoost(n_stumps=10)] * 2 + [StumpBoost(n_stumps=20)] * 2
    plan = plan_orgs(make_orgs(_xs(rng_np), models))
    assert plan.compiled and plan.n_groups == 2  # config is the signature


def test_per_org_loss_q_splits_groups(rng_np):
    losses = [lq_loss(2.0), lq_loss(4.0), lq_loss(2.0), lq_loss(4.0)]
    plan = plan_orgs(make_orgs(_xs(rng_np), Linear(), local_losses=losses))
    assert plan.compiled and plan.n_groups == 2
    assert plan.groups[0].indices == (0, 2)


def test_noise_sigma_splits_groups(rng_np):
    plan = plan_orgs(make_orgs(_xs(rng_np), Linear(),
                               noise_sigmas=[0.0, 0.5, 0.0, 0.5]))
    assert plan.compiled and plan.noisy and not plan.homogeneous
    assert plan.n_groups == 2
    assert plan.groups[1].noise_sigma == 0.5
    assert "sigma=0.5" in plan.describe()


def test_pad_invariant_model_mixes_widths_in_one_group(rng_np):
    xs = _xs(rng_np, d=13)                       # widths (4, 3, 3, 3)
    plan = plan_orgs(make_orgs(xs, StumpBoost()))
    assert plan.compiled and plan.n_groups == 1


def test_width_dependent_init_splits_per_width(rng_np):
    xs = _xs(rng_np, d=13)                       # widths (4, 3, 3, 3)
    plan = plan_orgs(make_orgs(xs, MLP((8,))))
    assert plan.compiled and plan.n_groups == 2
    assert plan.groups[0].size == 1 and plan.groups[1].size == 3
    assert any("width" in note for note in plan.notes)


def test_dms_compiles_into_its_own_group(rng_np):
    """DMS is no longer a fallback: a head-interface model (MLP) plans into
    a compiled DMS group keyed by its extractor signature; the plan is
    never 'homogeneous' (the extractor/head carry belongs to the grouped
    engine, not scan/shard)."""
    plan = plan_orgs(make_orgs(_xs(rng_np), MLP((8,)), dms=True))
    assert plan.compiled and plan.has_dms and not plan.homogeneous
    assert plan.n_groups == 1 and plan.groups[0].dms
    assert "DMS" in plan.describe()


def test_dms_and_fresh_fit_same_model_split_groups(rng_np):
    """The same MLP config with and without DMS must NOT share a vmapped
    group — their fits are different programs."""
    plan = plan_orgs(make_orgs(_xs(rng_np), MLP((8,)),
                               dms=[True, False, True, False]))
    assert plan.compiled and plan.n_groups == 2
    assert plan.groups[0].dms and not plan.groups[1].dms
    assert plan.groups[0].indices == (0, 2)


def test_dms_without_head_interface_is_a_reason(rng_np):
    """Linear has no features/init_head/apply_head: DMS cannot trace (and
    the reference engine could not run it either) — named in the reason."""
    plan = plan_orgs(make_orgs(_xs(rng_np), Linear(), dms=True))
    assert not plan.compiled
    assert "Deep Model Sharing" in plan.reason
    assert "features" in plan.reason or "init_head" in plan.reason


def test_non_scan_safe_model_named_in_reason(rng_np):
    class HostModel:
        scan_safe = False

        def fit(self, rng, x, r, loss):
            return {}

        def apply(self, params, x):
            return jnp.zeros((x.shape[0], 1))

    models = [Linear(), HostModel(), Linear(), Linear()]
    plan = plan_orgs(make_orgs(_xs(rng_np), models))
    assert not plan.compiled
    assert "HostModel" in plan.reason and "organization 1" in plan.reason


def test_custom_traceable_loss_compiles(rng_np):
    """A loss without a .q exponent compiles as long as it traces to a
    scalar: the engines differentiate it inside the scanned round step."""
    def pseudo_huber(r, f):
        return jnp.mean(jnp.sqrt(1.0 + jnp.square(r - f)) - 1.0)

    plan = plan_orgs(make_orgs(_xs(rng_np), Linear(),
                               local_losses=pseudo_huber))
    assert plan.compiled and plan.n_groups == 1
    assert "pseudo_huber" in plan.describe()


def test_distinct_custom_losses_split_groups(rng_np):
    """Custom losses group by callable identity — two different objects
    cannot share a vmapped fit."""
    def loss_a(r, f):
        return jnp.mean(jnp.square(r - f))

    def loss_b(r, f):
        return jnp.mean(jnp.abs(r - f) ** 3)

    plan = plan_orgs(make_orgs(_xs(rng_np), Linear(),
                               local_losses=[loss_a, loss_a, loss_b, loss_a]))
    assert plan.compiled and plan.n_groups == 2
    assert plan.groups[0].indices == (0, 1, 3)


def test_non_traceable_loss_named_in_reason(rng_np):
    def host_loss(r, f):
        import numpy as _np
        return float(_np.mean(_np.square(_np.asarray(r) - _np.asarray(f))))

    plan = plan_orgs(make_orgs(_xs(rng_np), Linear(), local_losses=host_loss))
    assert not plan.compiled and "not jax-traceable" in plan.reason
    assert "host_loss" in plan.reason


def test_sample_axis_mismatch_is_a_reason(rng_np):
    xs = _xs(rng_np)
    xs[1] = xs[1][:32]
    plan = plan_orgs(make_orgs(xs, Linear()))
    assert not plan.compiled and "sample axis" in plan.reason


def test_eval_width_mismatch_is_a_reason(rng_np):
    xs = _xs(rng_np)
    xs_e = [x[:16] for x in xs]
    xs_e[2] = xs_e[2][:, :2]                     # wrong eval width for org 2
    y_e = jnp.zeros((16, 1))
    plan = plan_orgs(make_orgs(xs, Linear()), {"test": (xs_e, y_e)})
    assert not plan.compiled and "width" in plan.reason


def test_plan_lm_orgs_groups_by_cfg(key):
    from repro.configs import get_arch
    from repro.core.gal_lm import LMOrganization

    cfg = get_arch("llama3-8b", smoke=True)
    orgs = [LMOrganization(i, cfg, lambda t: t) for i in range(2)]
    plan = plan_lm_orgs(orgs)
    assert not plan.compiled and "not initialized" in plan.reason
    import jax
    for i, org in enumerate(orgs):
        org.init(jax.random.fold_in(key, i), lr=1e-3)
    plan = plan_lm_orgs(orgs)
    assert plan.compiled and plan.n_groups == 1
    orgs[1].lr = 3e-3                            # differing optimizer setting
    assert plan_lm_orgs(orgs).n_groups == 2
