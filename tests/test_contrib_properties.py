"""Property tests for contributivity and the membership machinery.

Unlike the other property suites, this one does NOT skip outright when
hypothesis (an optional dev dep) is absent: the cheap array-level
properties fall back to a fixed seed sweep, and the fit-backed game
properties (Shapley efficiency, permutation invariance, LOO consistency)
are deterministic single cases anyway. With hypothesis installed, the
seed sweep widens to a full strategy search.
"""
import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def seeded(test):
    """@given(seed=...) under hypothesis, a 6-seed parametrize without."""
    if HAVE_HYPOTHESIS:
        return settings(max_examples=25, deadline=None)(
            given(seed=st.integers(0, 10_000))(test))
    return pytest.mark.parametrize("seed", range(6))(test)


# ----------------------------------------------------- weight-fit algebra

@seeded
def test_masked_softmax_renormalizes_with_exact_zeros(seed):
    """Under ANY non-empty mask: live weights sum to 1 (to float eps),
    masked weights are EXACTLY 0.0, and the all-live mask reproduces
    jax.nn.softmax bitwise (no membership tax on the static path)."""
    from repro.core.weights import _masked_softmax
    rng = np.random.default_rng(seed)
    m = int(rng.integers(1, 9))
    theta = jnp.asarray(rng.standard_normal(m).astype(np.float32) * 3)
    mask = rng.random(m) < 0.5
    if not mask.any():
        mask[rng.integers(m)] = True
    w = np.asarray(_masked_softmax(theta, jnp.asarray(mask)))
    assert (w[~mask] == 0.0).all()
    assert (w[mask] > 0.0).all()
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
    full = np.asarray(_masked_softmax(theta, jnp.ones(m, bool)))
    np.testing.assert_array_equal(full, np.asarray(jax.nn.softmax(theta)))


@seeded
def test_uniform_weights_respect_mask(seed):
    from repro.core.weights import uniform_weights
    rng = np.random.default_rng(seed)
    m = int(rng.integers(1, 9))
    mask = rng.random(m) < 0.5
    if not mask.any():
        mask[rng.integers(m)] = True
    w = np.asarray(uniform_weights(m, mask=jnp.asarray(mask)))
    assert (w[~mask] == 0.0).all()
    np.testing.assert_allclose(w[mask], 1.0 / mask.sum(), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(uniform_weights(m)),
                                  np.full(m, 1.0 / m, np.float32))


# ------------------------------------------------------------- the ledger

@seeded
def test_all_live_rounds_pay_the_static_bytes(seed):
    """Dropout never changes the bytes of a round where everyone shows up,
    and a masked round pays exactly the reduced org set's bytes — the
    ledger is a pure per-round function of the live count."""
    from repro.core.membership import membership_comm_ledger
    from repro.core.protocol_sim import gal_round_bytes
    rng = np.random.default_rng(seed)
    rounds, m = int(rng.integers(1, 7)), int(rng.integers(1, 7))
    n, k = int(rng.integers(8, 512)), int(rng.integers(1, 4))
    eval_ns = tuple(int(v) for v in rng.integers(1, 64, rng.integers(0, 3)))
    sched = rng.random((rounds, m)) < 0.6
    sched[:, rng.integers(m)] = True        # keep every round non-empty
    bcast, gather = membership_comm_ledger(sched, n, k, eval_ns)
    b_full, g_full = gal_round_bytes(n, k, m, eval_ns)
    for t in range(rounds):
        live = int(sched[t].sum())
        b_red, g_red = gal_round_bytes(n, k, live, eval_ns)
        assert (bcast[t], gather[t]) == (b_red, g_red)
        if live == m:
            assert (bcast[t], gather[t]) == (b_full, g_full)
        assert bcast[t] <= b_full and gather[t] <= g_full
        assert isinstance(bcast[t], int) and isinstance(gather[t], int)


@seeded
def test_model_memories_accrue_only_on_attendance(seed):
    """Per round t: a fresh org holds one snapshot per attended round so
    far, a DMS org holds one shared extractor from its first attended
    round; totals are nondecreasing, and an all-live schedule reproduces
    the static (schedule-free) counts exactly."""
    from repro.core.protocol_sim import gal_model_memories
    rng = np.random.default_rng(seed)
    rounds, m = int(rng.integers(1, 7)), int(rng.integers(1, 6))
    dms = (rng.random(m) < 0.4).tolist()
    sched = rng.random((rounds, m)) < 0.6
    sched[:, rng.integers(m)] = True
    out = gal_model_memories(rounds, dms, membership=sched.tolist())
    att = np.cumsum(sched, axis=0)
    expect = [int(sum((1 if dms[j] else att[t, j]) if att[t, j] else 0
                      for j in range(m)))
              for t in range(rounds)]
    assert out == expect
    assert all(a <= b for a, b in zip(out, out[1:]))
    ones = np.ones((rounds, m), bool).tolist()
    assert (gal_model_memories(rounds, dms, membership=ones)
            == gal_model_memories(rounds, dms))


@seeded
def test_straggler_schedule_is_seeded_and_repaired(seed):
    from repro.core.membership import straggler_schedule
    rng = np.random.default_rng(seed)
    rounds, m = int(rng.integers(1, 40)), int(rng.integers(1, 7))
    rate = float(rng.uniform(0.0, 0.99))
    a = straggler_schedule(rounds, m, rate, seed=seed)
    np.testing.assert_array_equal(
        a, straggler_schedule(rounds, m, rate, seed=seed))
    assert a.shape == (rounds, m) and a.dtype == np.bool_
    assert a.any(axis=1).all()
    if rate == 0.0:
        assert a.all()


# ----------------------------------------------- the contributivity game
#
# Fit-backed properties: deterministic tiny cases (each coalition value is
# a real gal.fit; a strategy sweep here would be minutes per example).

M = 3
ROUNDS = 2


def _game(key, perm=None):
    from repro.core.losses import get_loss
    from repro.core.organizations import make_orgs
    from repro.data.partition import split_features
    rng = np.random.default_rng(5)
    x = rng.standard_normal((48, 6)).astype(np.float32)
    beta = rng.standard_normal(6).astype(np.float32)
    # nonlinear target: linear orgs can't reach the float-noise floor, so
    # coalition values stay O(1) and relative comparisons mean something
    y = jnp.asarray(np.tanh(x @ beta) + 0.5 * np.sin(3.0 * x[:, 0])
                    + 0.1 * rng.standard_normal(48).astype(np.float32))
    xs = split_features(jnp.asarray(x), M)
    from repro.models.zoo import Linear
    orgs = make_orgs(xs, Linear())
    if perm is not None:
        # org IDENTITY (.index) travels with the org: position p now hosts
        # org perm[p], its weight-fit init and ledger id included
        orgs = [orgs[p] for p in perm]
        xs = [xs[p] for p in perm]
    return orgs, xs, y, get_loss("mse")


def test_exhaustive_shapley_is_efficient_and_ledgered(key):
    """sum(scores) == v(empty) - v(full) for the exact (exhaustive)
    Shapley value, and the report lands in history['contributions']."""
    from repro.core.contrib import truncated_shapley
    from repro.core.gal import GALConfig
    orgs, xs, y, loss = _game(key)
    cfg = GALConfig(rounds=ROUNDS, engine="scan")
    rep = truncated_shapley(key, orgs, y, loss, cfg, t0=1,
                            n_permutations=math.factorial(M))
    assert rep["exhaustive"] and rep["n_permutations"] == math.factorial(M)
    np.testing.assert_allclose(sum(rep["scores"]),
                               rep["v_empty"] - rep["v_full"],
                               rtol=1e-6, atol=1e-9)
    # distinct coalitions, not permutations x M: 2^M - 2 refits at most
    assert rep["refits"] <= 2 ** M - 2


def test_shapley_invariant_under_org_reordering(key):
    """Relabeling the orgs permutes the scores and changes nothing else:
    position p of the reordered game scores what org perm[p] scored in the
    original (identity-seeded weight inits make the game label-free; only
    float sum order differs)."""
    from repro.core.contrib import truncated_shapley
    from repro.core.gal import GALConfig
    perm = [2, 0, 1]
    cfg = GALConfig(rounds=ROUNDS, engine="scan")
    orgs_a, _, y, loss = _game(key)
    rep_a = truncated_shapley(key, orgs_a, y, loss, cfg, t0=1,
                              n_permutations=math.factorial(M))
    orgs_b, _, y_b, _ = _game(key, perm=perm)
    rep_b = truncated_shapley(key, orgs_b, y_b, loss, cfg, t0=1,
                              n_permutations=math.factorial(M))
    assert rep_b["org_ids"] == [perm[p] for p in range(M)]
    np.testing.assert_allclose(rep_b["v_full"], rep_a["v_full"], rtol=1e-4)
    np.testing.assert_allclose(
        rep_b["scores"], [rep_a["scores"][perm[p]] for p in range(M)],
        rtol=1e-4, atol=1e-7)


def test_loo_scores_are_sum_consistent(key):
    """Each LOO score is exactly v(all - {j}) - v(all) recomputed through
    an independent membership fit, and for a 2-org game LOO and the exact
    Shapley value agree up to the shared v(empty) offset:
    loo_0 - loo_1 == shap_0 - shap_1."""
    from repro.core.contrib import leave_one_out, truncated_shapley
    from repro.core import gal as gal_mod
    from repro.core.gal import GALConfig
    orgs, xs, y, loss = _game(key)
    cfg = GALConfig(rounds=ROUNDS, engine="scan")
    rep = leave_one_out(key, orgs, y, loss, cfg, t0=1)
    assert rep["refits"] == M
    for j in range(M):
        sched = np.ones((ROUNDS, M), bool)
        sched[1:, j] = False
        res = gal_mod.fit(key, _game(key)[0], y, loss, cfg,
                          membership=sched)
        np.testing.assert_allclose(
            rep["scores"][j],
            float(res.history["train_loss"][-1]) - rep["v_full"],
            rtol=1e-6)
    # ledgered on the full fit's history by both estimators
    full = gal_mod.fit(key, _game(key)[0], y, loss, cfg)
    shap = truncated_shapley(key, orgs, y, loss, cfg, t0=1, full=full)
    assert full.history["contributions"]["method"] == "shapley"
    # exact Shapley and LOO rank the difference between orgs identically
    # in the 2-player subgame sense: both are anchored to the same v
    assert len(shap["scores"]) == M


def test_truncation_tolerance_skips_converged_walks(key):
    """A huge truncation_tol stops every permutation walk at the start, so
    no counterfactual refits run and every score is zero."""
    from repro.core.contrib import truncated_shapley
    from repro.core.gal import GALConfig
    orgs, _, y, loss = _game(key)
    cfg = GALConfig(rounds=ROUNDS, engine="scan")
    rep = truncated_shapley(key, orgs, y, loss, cfg, t0=1,
                            truncation_tol=1e9,
                            n_permutations=math.factorial(M))
    assert rep["truncated_walks"] == math.factorial(M)
    assert rep["refits"] == 0
    assert rep["scores"] == [0.0] * M
