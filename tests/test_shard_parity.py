"""Org-sharded multi-device engine == scan fast path == Python reference.

The shard engine replays Algorithm 1 with identical RNG discipline but maps
the org axis onto a real device mesh (one organization per device) and runs
the round's communication as real collectives. For deterministic local fits
every recorded quantity — etas, assistance weights, train/eval history —
must agree with the scan engine to float tolerance, and the per-round
communication ledger must report the Table-14 byte counts.

Run with REPRO_FORCE_DEVICES=4 (the tests/conftest.py shim splits the host
CPU into virtual devices); on a single device the suite skips.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import gal
from repro.core.engine import shard_eligible
from repro.core.gal import GALConfig
from repro.core.losses import get_loss
from repro.core.organizations import make_orgs
from repro.core.protocol_sim import gal_cost
from repro.data.partition import split_features
from repro.data.synthetic import make_regression, train_test_split
from repro.metrics.metrics import mad
from repro.models.zoo import Linear

M = 4
needs_org_mesh = pytest.mark.skipif(
    jax.device_count() < M or jax.device_count() % M != 0,
    reason=f"shard engine needs {M} | device_count; "
           f"run with REPRO_FORCE_DEVICES={M}")


def _setting(rng_np, m=M, d=12, n=200):
    ds = make_regression(rng_np, n=n, d=d)
    tr, te = train_test_split(ds, rng_np)
    return split_features(tr.x, m), tr.y, split_features(te.x, m), te.y


def _both(key, xs, y, loss, cfg, **kw):
    res_sc = gal.fit(key, make_orgs(xs, Linear()), y, loss,
                     dataclasses.replace(cfg, engine="scan"), **kw)
    res_sh = gal.fit(key, make_orgs(xs, Linear()), y, loss,
                     dataclasses.replace(cfg, engine="shard"), **kw)
    return res_sc, res_sh


@needs_org_mesh
def test_auto_prefers_shard_on_org_mesh(rng_np, key):
    xs, y, _, _ = _setting(rng_np)
    res = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                  GALConfig(rounds=2))
    assert res.engine == "shard"
    # per-round params keep the stacked (T, M, ...) contract of the scan path
    leaves = jax.tree_util.tree_leaves(res.stacked_params)
    assert all(l.shape[:2] == (2, M) for l in leaves)


@needs_org_mesh
def test_parity_etas_weights_history(rng_np, key):
    xs, y, xs_te, y_te = _setting(rng_np)
    res_sc, res_sh = _both(key, xs, y, get_loss("mse"), GALConfig(rounds=5),
                           eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
    np.testing.assert_allclose(res_sh.etas, res_sc.etas, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.stack(res_sh.weights),
                               np.stack(res_sc.weights), atol=1e-4)
    for col in ("train_loss", "test_loss", "test_metric"):
        np.testing.assert_allclose(res_sh.history[col], res_sc.history[col],
                                   rtol=1e-3, atol=1e-4, err_msg=col)


@needs_org_mesh
def test_parity_vs_python_reference(rng_np, key):
    xs, y, _, _ = _setting(rng_np)
    res_py = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                     GALConfig(rounds=4, engine="python"))
    res_sh = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                     GALConfig(rounds=4, engine="shard"))
    np.testing.assert_allclose(res_sh.etas, res_py.etas, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(res_sh.history["train_loss"],
                               res_py.history["train_loss"],
                               rtol=1e-3, atol=1e-4)


@needs_org_mesh
def test_parity_on_unequal_split_needs_padding(rng_np, key):
    """d=13 over 4 orgs -> widths (4,3,3,3); per-device zero-pad is inert."""
    xs, y, _, _ = _setting(rng_np, d=13)
    assert len({x.shape[-1] for x in xs}) > 1
    res_sc, res_sh = _both(key, xs, y, get_loss("mse"), GALConfig(rounds=3))
    np.testing.assert_allclose(res_sh.etas, res_sc.etas, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(res_sh.history["train_loss"],
                               res_sc.history["train_loss"],
                               rtol=1e-3, atol=1e-4)


@needs_org_mesh
def test_comm_ledger_matches_protocol_accounting(rng_np, key):
    """Per-round collective bytes == Table-14 convention (protocol_sim):
    broadcast (M-1) residual copies, gather M fitted-value tensors."""
    rounds, n = 3, 200
    xs, y, _, _ = _setting(rng_np, n=n)
    res = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                  GALConfig(rounds=rounds, engine="shard"))
    n_tr, k = y.shape[0], y.shape[-1]
    expect = gal_cost(n_tr, k, M, rounds)
    bcast = res.history["comm_broadcast_bytes"]
    gather = res.history["comm_gather_bytes"]
    assert len(bcast) == len(gather) == rounds
    assert all(b > 0 for b in bcast) and all(g > 0 for g in gather)
    assert sum(bcast) == expect.bytes_broadcast
    assert sum(gather) == expect.bytes_gathered


@needs_org_mesh
def test_comm_ledger_counts_eval_gather(rng_np, key):
    """Eval-set predictions are also collected over the org axis; the ledger
    charges them to the gather side on top of the training fitted values."""
    xs, y, xs_te, y_te = _setting(rng_np)
    res = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                  GALConfig(rounds=2, engine="shard"),
                  eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
    n_tr, n_te, k = y.shape[0], y_te.shape[0], y.shape[-1]
    per_round = M * (n_tr + n_te) * k * 4
    assert res.history["comm_gather_bytes"] == [per_round] * 2


@needs_org_mesh
def test_shard_predict_matches_scan_predict(rng_np, key):
    xs, y, xs_te, _ = _setting(rng_np, d=13)
    res_sc, res_sh = _both(key, xs, y, get_loss("mse"), GALConfig(rounds=3))
    np.testing.assert_allclose(np.asarray(res_sh.predict(xs_te)),
                               np.asarray(res_sc.predict(xs_te)),
                               rtol=1e-3, atol=1e-4)


@needs_org_mesh
def test_shard_respects_eta_stop_threshold(rng_np, key):
    xs, y, _, _ = _setting(rng_np)
    res = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                  GALConfig(rounds=10, eta_stop_threshold=10.0,
                            engine="shard"))
    assert res.rounds == 1
    assert len(res.history["train_loss"]) == 2
    assert len(res.history["comm_broadcast_bytes"]) == 1


@needs_org_mesh
def test_shard_raises_when_orgs_do_not_divide_devices(rng_np, key):
    d = jax.device_count()
    m_bad = d + 1  # never divides d
    xs, y, _, _ = _setting(rng_np, m=m_bad, d=2 * m_bad)
    with pytest.raises(ValueError, match="divide"):
        gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                GALConfig(rounds=1, engine="shard"))


@needs_org_mesh
def test_comm_ledger_engine_independent_vs_shard(rng_np, key):
    """Satellite: the scan and python engines' simulated ledgers equal the
    shard engine's real-collective byte counts, exact int for exact int."""
    rounds = 3
    xs, y, xs_te, y_te = _setting(rng_np)
    kw = dict(eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
    cfg = GALConfig(rounds=rounds)
    res_sh = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                     dataclasses.replace(cfg, engine="shard"), **kw)
    for engine in ("scan", "python"):
        res = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                      dataclasses.replace(cfg, engine=engine), **kw)
        assert res.history["comm_broadcast_bytes"] == \
            res_sh.history["comm_broadcast_bytes"], engine
        assert res.history["comm_gather_bytes"] == \
            res_sh.history["comm_gather_bytes"], engine


@needs_org_mesh
def test_grouped_mesh_maps_mixed_models_onto_devices(rng_np, key):
    """A mixed-model org set whose group sizes divide the device count runs
    the grouped engine with its org stacks SHARDED over the mesh — and
    still matches the Python reference. Well-conditioned closed-form local
    fits keep the parity continuous (narrow slices drive the RBF gram
    near-singular and f32 solve noise through the roof; argmax-based
    stump fits can flip discretely under reduction-order changes — both
    are covered by the loss-level checks in tests/test_grouped_parity.py
    instead)."""
    from repro.models.zoo import KernelRidge
    d_count = jax.device_count()
    m = 2 * d_count                      # two groups of d_count orgs each
    xs, y, xs_te, _ = _setting(rng_np, m=m, d=4 * m)
    models = [Linear() if i < d_count else KernelRidge(reg=1.0)
              for i in range(m)]
    res_py = gal.fit(key, make_orgs(xs, models), y, get_loss("mse"),
                     GALConfig(rounds=2, engine="python"))
    res_gr = gal.fit(key, make_orgs(xs, models), y, get_loss("mse"),
                     GALConfig(rounds=2, engine="shard"))
    assert res_gr.engine == "grouped"
    assert res_gr.mesh_devices == d_count
    np.testing.assert_allclose(res_gr.etas, res_py.etas,
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(res_gr.history["train_loss"],
                               res_py.history["train_loss"],
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(res_gr.predict(xs_te)),
                               np.asarray(res_py.predict(xs_te)),
                               rtol=1e-3, atol=1e-3)


@needs_org_mesh
def test_fig4_protocol_on_shard_engine(rng_np, key):
    """predict(xs_eval, rounds=t) reproduces the recorded eval curve on the
    org-sharded engine (the shard leg of tests/test_validation_protocol)."""
    xs, y, xs_te, y_te = _setting(rng_np)
    loss = get_loss("mse")
    res = gal.fit(key, make_orgs(xs, Linear()), y, loss,
                  GALConfig(rounds=3, engine="shard"),
                  eval_sets={"test": (xs_te, y_te)})
    curve = res.history["test_loss"]
    for t in range(res.rounds + 1):
        np.testing.assert_allclose(
            float(loss(y_te, res.predict(xs_te, rounds=t))), curve[t],
            rtol=1e-4, atol=1e-5, err_msg=f"round {t}")


def test_shard_ineligible_on_single_device(rng_np, key):
    """Runs in ANY device configuration: eligibility tracks the mesh rule
    (1:1 when M divides the device count, block placement when the device
    count divides M), and auto never crashes."""
    xs, y, _, _ = _setting(rng_np)
    orgs = make_orgs(xs, Linear())
    d = jax.device_count()
    assert shard_eligible(orgs) == (d > 1 and (d % M == 0 or M % d == 0))
    res = gal.fit(key, orgs, y, get_loss("mse"), GALConfig(rounds=1))
    assert res.engine == ("shard" if shard_eligible(orgs) else "scan")
