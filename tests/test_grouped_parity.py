"""Grouped fused engine == Python reference on the heterogeneous scenarios.

The grouped engine replays Algorithm 1 with the reference engine's exact
RNG discipline — ``fold_in(k_round, org.index)`` per fit, ``fold_in(org_key,
777)`` training noise, ``fold_in(PRNGKey(org.index), t)`` prediction noise —
so for deterministic local fits every recorded quantity (etas, assistance
weights, train/eval history, predictions) must agree to float tolerance on:

  * a heterogeneous GB–SVM-style model mix (paper Sec. 4.2 model autonomy),
  * per-org local ell_q exponents,
  * noisy organizations (paper Table 6), draw for draw,
  * combinations of the above.

Also covered: the engine-independent communication ledger (scan / grouped /
python vs the protocol_sim oracle) and the deduplicated planner-reason
error path.
"""
import numpy as np
import pytest
import jax

from repro.core import gal
from repro.core.gal import GALConfig
from repro.core.losses import get_loss, lq_loss
from repro.core.organizations import make_orgs
from repro.core.protocol_sim import gal_cost, gal_round_bytes
from repro.data.partition import split_features
from repro.data.synthetic import make_regression, train_test_split
from repro.metrics.metrics import mad
from repro.models.zoo import KernelRidge, Linear, StumpBoost


def _setting(rng_np, m=4, d=12, n=200):
    ds = make_regression(rng_np, n=n, d=d)
    tr, te = train_test_split(ds, rng_np)
    return split_features(tr.x, m), tr.y, split_features(te.x, m), te.y


def _mix(m=4, n_stumps=8):
    return [StumpBoost(n_stumps=n_stumps) if i % 2 == 0 else KernelRidge()
            for i in range(m)]


def _parity(res_a, res_b, cols=("train_loss",), predict=None):
    # f32 tolerance tier of the existing cross-engine suites: eta/weight
    # drift accumulates over rounds through the weight-fit Adam scans
    np.testing.assert_allclose(res_a.etas, res_b.etas, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.stack(res_a.weights),
                               np.stack(res_b.weights), atol=1e-3)
    for col in cols:
        np.testing.assert_allclose(res_a.history[col], res_b.history[col],
                                   rtol=1e-3, atol=1e-3, err_msg=col)
    if predict is not None:
        # predictions compound the per-round eta/weight drift through every
        # org model's vmap-vs-loop float divergence (batched vs single
        # kernel solves, stump split ties), so they get one tolerance tier
        # more than the histories
        np.testing.assert_allclose(np.asarray(res_a.predict(predict)),
                                   np.asarray(res_b.predict(predict)),
                                   rtol=1e-3, atol=5e-3)


def test_hetero_gb_svm_mix_parity(rng_np, key):
    xs, y, xs_te, y_te = _setting(rng_np)
    kw = dict(eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
    res_py = gal.fit(key, make_orgs(xs, _mix()), y, get_loss("mse"),
                     GALConfig(rounds=4, engine="python"), **kw)
    res_gr = gal.fit(key, make_orgs(xs, _mix()), y, get_loss("mse"),
                     GALConfig(rounds=4, engine="grouped"), **kw)
    assert res_gr.engine == "grouped" and res_gr.plan.n_groups == 2
    _parity(res_gr, res_py,
            cols=("train_loss", "test_loss", "test_metric"), predict=xs_te)


def test_auto_selects_grouped_for_mixed_models(rng_np, key):
    xs, y, _, _ = _setting(rng_np)
    res = gal.fit(key, make_orgs(xs, _mix()), y, get_loss("mse"),
                  GALConfig(rounds=2))
    assert res.engine == "grouped"
    # per-group stacked params keep the (T, M_g, ...) contract
    for g, params in zip(res.plan.groups, res.group_params):
        leaves = jax.tree_util.tree_leaves(params)
        assert all(l.shape[:2] == (2, g.size) for l in leaves)


def test_per_org_loss_q_parity(rng_np, key):
    xs, y, xs_te, y_te = _setting(rng_np)
    losses = [lq_loss(2.0), lq_loss(2.0), lq_loss(4.0), lq_loss(4.0)]
    kw = dict(eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
    res_py = gal.fit(key, make_orgs(xs, Linear(), local_losses=losses), y,
                     get_loss("mse"), GALConfig(rounds=3, engine="python"),
                     **kw)
    res_gr = gal.fit(key, make_orgs(xs, Linear(), local_losses=losses), y,
                     get_loss("mse"), GALConfig(rounds=3), **kw)
    assert res_gr.engine == "grouped" and res_gr.plan.n_groups == 2
    _parity(res_gr, res_py, cols=("train_loss", "test_loss"), predict=xs_te)


def test_noisy_orgs_parity_draw_for_draw(rng_np, key):
    """The satellite regression test: with fold_in-derived noise keys the
    grouped engine and the Python reference draw IDENTICAL training- and
    prediction-stage noise, so noisy parity holds to float tolerance —
    including the per-round eval history and post-fit predictions."""
    xs, y, xs_te, y_te = _setting(rng_np)
    sig = [0.0, 1.0, 0.0, 1.0]
    kw = dict(eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
    res_py = gal.fit(key, make_orgs(xs, Linear(), noise_sigmas=sig), y,
                     get_loss("mse"), GALConfig(rounds=4, engine="python"),
                     **kw)
    res_gr = gal.fit(key, make_orgs(xs, Linear(), noise_sigmas=sig), y,
                     get_loss("mse"), GALConfig(rounds=4), **kw)
    assert res_gr.engine == "grouped"
    assert res_gr.plan.noisy and res_gr.plan.n_groups == 2
    _parity(res_gr, res_py,
            cols=("train_loss", "test_loss", "test_metric"), predict=xs_te)


def test_noisy_hetero_combination_parity(rng_np, key):
    xs, y, xs_te, y_te = _setting(rng_np)
    res_py = gal.fit(key, make_orgs(xs, _mix(), noise_sigmas=[0.5] * 4), y,
                     get_loss("mse"), GALConfig(rounds=2, engine="python"))
    res_gr = gal.fit(key, make_orgs(xs, _mix(), noise_sigmas=[0.5] * 4), y,
                     get_loss("mse"), GALConfig(rounds=2))
    assert res_gr.plan.n_groups == 2 and res_gr.plan.noisy
    _parity(res_gr, res_py, predict=xs_te)


def test_grouped_respects_eta_stop_threshold(rng_np, key):
    xs, y, _, _ = _setting(rng_np)
    res = gal.fit(key, make_orgs(xs, _mix()), y, get_loss("mse"),
                  GALConfig(rounds=10, eta_stop_threshold=10.0,
                            engine="grouped"))
    assert res.rounds == 1
    assert len(res.history["train_loss"]) == 2
    for params in res.group_params:
        assert all(l.shape[0] == 1
                   for l in jax.tree_util.tree_leaves(params))


def test_grouped_predict_rejects_mismatched_slices(rng_np, key):
    xs, y, xs_te, _ = _setting(rng_np, d=13)
    res = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                  GALConfig(rounds=2, engine="grouped"))
    with pytest.raises(ValueError, match="widths"):
        res.predict(list(reversed(xs_te)))       # wrong org order


def test_grouped_unpack_to_orgs_restores_legacy_path(rng_np, key):
    """unpack_to_orgs is plan-aware: per-round params land back on the
    RIGHT org even though groups permute the org order."""
    from repro.data.partition import stack_groups
    xs, y, xs_te, _ = _setting(rng_np)
    res = gal.fit(key, make_orgs(xs, _mix()), y, get_loss("mse"),
                  GALConfig(rounds=3, engine="grouped"))
    pred_fast = np.asarray(res.predict(xs_te))
    res.unpack_to_orgs()
    stacks, _, _ = stack_groups(xs_te, [g.indices for g in res.plan.groups],
                                pad_tos=res.group_pads)
    xs_padded = list(xs_te)
    for g, st in zip(res.plan.groups, stacks):
        for j, i in enumerate(g.indices):
            xs_padded[i] = st[j]
    np.testing.assert_allclose(pred_fast,
                               np.asarray(res.predict_legacy(xs_padded)),
                               rtol=1e-4, atol=1e-5)


def test_comm_ledger_engine_independent_single_host(rng_np, key):
    """scan / grouped / python all record the simulated Table-14 ledger with
    identical exact ints (protocol_sim.gal_round_bytes is the one source);
    totals match the gal_cost oracle."""
    rounds = 3
    xs, y, xs_te, y_te = _setting(rng_np)
    kw = dict(eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
    res_py = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                     GALConfig(rounds=rounds, engine="python"), **kw)
    res_sc = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                     GALConfig(rounds=rounds, engine="scan"), **kw)
    res_gr = gal.fit(key, make_orgs(xs, _mix()), y, get_loss("mse"),
                     GALConfig(rounds=rounds, engine="grouped"), **kw)
    n, k = y.shape[0], y.shape[-1]
    bcast, gather = gal_round_bytes(n, k, 4, [y_te.shape[0]])
    for res in (res_py, res_sc, res_gr):
        assert res.history["comm_broadcast_bytes"] == [bcast] * rounds
        assert res.history["comm_gather_bytes"] == [gather] * rounds
        assert all(isinstance(b, int)
                   for b in res.history["comm_broadcast_bytes"])
    # totals without eval sets == the Table-14 oracle
    res_plain = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                        GALConfig(rounds=rounds, engine="scan"))
    expect = gal_cost(n, k, 4, rounds)
    assert sum(res_plain.history["comm_broadcast_bytes"]) == \
        expect.bytes_broadcast
    assert sum(res_plain.history["comm_gather_bytes"]) == \
        expect.bytes_gathered


def test_python_ledger_trims_on_early_stop(rng_np, key):
    xs, y, _, _ = _setting(rng_np)
    res = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                  GALConfig(rounds=10, eta_stop_threshold=10.0,
                            engine="python"))
    assert res.rounds == 1
    assert len(res.history["comm_broadcast_bytes"]) == 1


def test_forced_engines_share_one_planner_reason_path(rng_np, key):
    """Satellite: the scan/shard/grouped ineligibility errors are ONE code
    path surfacing the planner's human-readable reason. DMS compiles now,
    so the probe is a genuinely non-compilable set: a model that is not
    scan-safe."""
    class HostModel:
        scan_safe = False

        def fit(self, rng, x, r, loss):
            return {}

        def apply(self, params, x):
            import jax.numpy as jnp
            return jnp.zeros((x.shape[0], 1))

    xs, y, _, _ = _setting(rng_np)
    bad_orgs = lambda: make_orgs(xs, HostModel())  # noqa: E731
    msgs = []
    for engine in ("scan", "shard", "grouped"):
        with pytest.raises(ValueError) as ei:
            gal.fit(key, bad_orgs(), y, get_loss("mse"),
                    GALConfig(rounds=1, engine=engine))
        msgs.append(str(ei.value))
    for engine, msg in zip(("scan", "shard", "grouped"), msgs):
        assert f"engine={engine!r} cannot compile" in msg
        assert "not scan-safe" in msg


def test_dms_without_head_interface_raises_on_any_engine(rng_np, key):
    """A DMS org whose model lacks features/init_head/apply_head cannot run
    anywhere — not even the python reference (it needs the same surface).
    auto/python must surface the planner's reason up front instead of an
    AttributeError three steps into round 0."""
    xs, y, _, _ = _setting(rng_np)
    for engine in ("auto", "python", "grouped"):
        with pytest.raises(ValueError, match="Deep Model Sharing"):
            gal.fit(key, make_orgs(xs, Linear(), dms=True), y,
                    get_loss("mse"),
                    GALConfig(rounds=1, engine=engine))


def test_duck_typed_dms_model_still_runs_on_python(rng_np, key):
    """The flip side: a duck-typed model WITH the full extractor/head
    interface but no scan_safe declaration is not compilable, but the
    reference DMS loop runs it fine — auto must fall back, not raise."""
    import jax.numpy as jnp

    class DuckDMS:                       # no scan_safe attribute at all
        lr, epochs = 1e-2, 3

        def init(self, rng, x, k_out):
            d = x.shape[-1]
            kw, kh = jax.random.split(rng)
            return {"w": jax.random.normal(kw, (d, 4)) / jnp.sqrt(d),
                    "head": self.init_head(kh, k_out)}

        def features(self, params, x):
            return jnp.tanh(x @ params["w"])

        def init_head(self, rng, k_out):
            return {"w": jax.random.normal(rng, (4, k_out)) * 0.1,
                    "b": jnp.zeros((k_out,))}

        def apply_head(self, head, h):
            return h @ head["w"] + head["b"]

    xs, y, _, _ = _setting(rng_np, n=60)
    orgs = make_orgs(xs, DuckDMS(), dms=True)
    res = gal.fit(key, orgs, y, get_loss("mse"), GALConfig(rounds=2))
    assert res.engine == "python"
    assert all(len(org._dms_heads) == 2 for org in orgs)


def test_grouped_engine_with_privacy_runs(rng_np, key):
    xs, y, _, _ = _setting(rng_np)
    res = gal.fit(key, make_orgs(xs, _mix()), y, get_loss("mse"),
                  GALConfig(rounds=2, privacy="dp", privacy_alpha=5.0,
                            engine="grouped"))
    assert res.engine == "grouped"
    assert np.isfinite(res.history["train_loss"]).all()


def test_host_metric_is_rejected_on_every_engine(rng_np, key):
    """The host-side metric escape hatch is retired: metrics run
    device-side inside the round loop on EVERY engine (python included),
    so a non-traceable callable raises up front, naming the registry."""
    xs, y, xs_te, y_te = _setting(rng_np)

    def host_metric(y_true, f):
        return float(np.mean(np.abs(np.asarray(y_true) - np.asarray(f))))

    for engine in ("python", "grouped", "auto"):
        with pytest.raises(ValueError, match="repro.metrics.METRICS"):
            gal.fit(key, make_orgs(xs, _mix()), y, get_loss("mse"),
                    GALConfig(rounds=1, engine=engine),
                    eval_sets={"test": (xs_te, y_te)},
                    metric_fn=host_metric)


def test_registry_metrics_device_side_parity(rng_np, key):
    """gal.fit(metrics=("mad",)) records history["<eval>_mad"] inside the
    single host sync; python and grouped agree, and the registry column
    equals the legacy metric_fn column."""
    xs, y, xs_te, y_te = _setting(rng_np)
    kw = dict(eval_sets={"test": (xs_te, y_te)}, metrics=("mad",))
    res_py = gal.fit(key, make_orgs(xs, _mix()), y, get_loss("mse"),
                     GALConfig(rounds=3, engine="python"), **kw)
    res_gr = gal.fit(key, make_orgs(xs, _mix()), y, get_loss("mse"),
                     GALConfig(rounds=3, engine="grouped"), **kw)
    np.testing.assert_allclose(res_py.history["test_mad"],
                               res_gr.history["test_mad"],
                               rtol=1e-3, atol=1e-3)
    res_legacy = gal.fit(key, make_orgs(xs, _mix()), y, get_loss("mse"),
                         GALConfig(rounds=3, engine="grouped"),
                         eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
    np.testing.assert_allclose(res_gr.history["test_mad"],
                               res_legacy.history["test_metric"],
                               rtol=1e-6)
