"""Scan fast path == Python reference path (the fused-engine contract).

The fused engine replays Algorithm 1 with identical RNG discipline, so for
deterministic local fits (closed-form ridge) every recorded quantity — etas,
assistance weights, train/eval loss history — must agree with the reference
engine to float tolerance, including on unequal vertical splits where the
fast path zero-pads the org slices.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import gal
from repro.core.engine import scan_compatible, shard_eligible
from repro.core.gal import GALConfig
from repro.core.losses import get_loss
from repro.core.organizations import make_orgs
from repro.data.partition import pad_and_stack, split_features
from repro.data.synthetic import make_blobs, make_regression, train_test_split
from repro.metrics.metrics import accuracy, mad
from repro.models.zoo import KernelRidge, Linear, StumpBoost


def _setting(rng_np, m=4, d=12, n=400):
    ds = make_regression(rng_np, n=n, d=d)
    tr, te = train_test_split(ds, rng_np)
    return split_features(tr.x, m), tr.y, split_features(te.x, m), te.y


def _both_engines(key, xs, y, loss, cfg, **kw):
    import dataclasses
    res_py = gal.fit(key, make_orgs(xs, Linear()), y, loss,
                     dataclasses.replace(cfg, engine="python"), **kw)
    res_sc = gal.fit(key, make_orgs(xs, Linear()), y, loss,
                     dataclasses.replace(cfg, engine="scan"), **kw)
    return res_py, res_sc


def test_auto_selects_scan_for_homogeneous_orgs(rng_np, key):
    xs, y, _, _ = _setting(rng_np)
    orgs = make_orgs(xs, Linear())
    # on an org mesh (e.g. REPRO_FORCE_DEVICES=4) auto prefers the sharded
    # engine; both fast paths share the stacked-params contract below
    expected = "shard" if shard_eligible(orgs) else "scan"
    res = gal.fit(key, orgs, y, get_loss("mse"), GALConfig(rounds=2))
    assert res.engine == expected
    assert res.stacked_params is not None
    # stacked pytree: leaves carry (T, M, ...) leading dims
    leaves = jax.tree_util.tree_leaves(res.stacked_params)
    assert all(l.shape[:2] == (2, 4) for l in leaves)


def test_parity_etas_weights_history(rng_np, key):
    xs, y, xs_te, y_te = _setting(rng_np)
    res_py, res_sc = _both_engines(
        key, xs, y, get_loss("mse"), GALConfig(rounds=5),
        eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
    np.testing.assert_allclose(res_sc.etas, res_py.etas, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.stack(res_sc.weights),
                               np.stack(res_py.weights), atol=1e-4)
    for colname in ("train_loss", "test_loss", "test_metric"):
        np.testing.assert_allclose(res_sc.history[colname],
                                   res_py.history[colname],
                                   rtol=1e-3, atol=1e-4, err_msg=colname)


def test_parity_on_unequal_split_needs_padding(rng_np, key):
    """d=13 over 4 orgs -> slice widths (4,3,3,3); the zero-pad must be inert."""
    xs, y, _, _ = _setting(rng_np, d=13)
    assert len({x.shape[-1] for x in xs}) > 1
    res_py, res_sc = _both_engines(key, xs, y, get_loss("mse"),
                                   GALConfig(rounds=4))
    np.testing.assert_allclose(res_sc.etas, res_py.etas, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(res_sc.history["train_loss"],
                               res_py.history["train_loss"],
                               rtol=1e-3, atol=1e-4)


def test_parity_classification_xent(rng_np, key):
    ds = make_blobs(rng_np, n=150, d=10, k=5)
    tr, te = train_test_split(ds, rng_np)
    xs, xs_te = split_features(tr.x, 4), split_features(te.x, 4)
    res_py, res_sc = _both_engines(
        key, xs, tr.y, get_loss("xent"), GALConfig(rounds=4),
        eval_sets={"test": (xs_te, te.y)}, metric_fn=accuracy)
    np.testing.assert_allclose(res_sc.etas, res_py.etas, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(res_sc.history["test_metric"],
                               res_py.history["test_metric"], atol=0.5)


def test_stacked_predict_equivalence(rng_np, key):
    """One-vmap stacked prediction == per-(round, org) Python assembly, on
    the SAME fitted params (unpacked back into the Organization objects)."""
    xs, y, xs_te, y_te = _setting(rng_np, d=13)
    res = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                  GALConfig(rounds=4, engine="scan"))
    pred_fast = np.asarray(res.predict(xs_te))

    res.unpack_to_orgs()
    xe_stack, _ = pad_and_stack(xs_te, pad_to=res.pad_to)
    n = xs_te[0].shape[0]
    f = jnp.broadcast_to(res.f0, (n, res.f0.shape[-1]))
    for t in range(res.rounds):
        preds = jnp.stack([org.predict_round(t, xe_stack[m])
                           for m, org in enumerate(res.orgs)])
        f = f + res.etas[t] * jnp.einsum("m,mnk->nk", res.weights[t], preds)
    np.testing.assert_allclose(pred_fast, np.asarray(f), rtol=1e-4, atol=1e-5)

    # and against the reference engine's own predict
    res_py = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                     GALConfig(rounds=4, engine="python"))
    np.testing.assert_allclose(pred_fast, np.asarray(res_py.predict(xs_te)),
                               rtol=1e-3, atol=1e-3)


def test_predict_rounds_truncation(rng_np, key):
    xs, y, xs_te, _ = _setting(rng_np)
    res = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                  GALConfig(rounds=3, engine="scan"))
    p0 = np.asarray(res.predict(xs_te, rounds=0))
    np.testing.assert_allclose(p0, np.broadcast_to(np.asarray(res.f0),
                                                   p0.shape))
    assert not np.allclose(p0, np.asarray(res.predict(xs_te, rounds=2)))


def test_scan_respects_eta_stop_threshold(rng_np, key):
    xs, y, _, _ = _setting(rng_np)
    res = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                  GALConfig(rounds=10, eta_stop_threshold=10.0, engine="scan"))
    assert res.rounds == 1
    assert len(res.history["train_loss"]) == 2
    leaves = jax.tree_util.tree_leaves(res.stacked_params)
    assert all(l.shape[0] == 1 for l in leaves)


def test_pad_invariant_models_parity_on_unequal_split(rng_np, key):
    """KernelRidge/StumpBoost fits are exactly pad-invariant: scan == python
    even when the org slices are zero-padded."""
    xs, y, _, _ = _setting(rng_np, d=13, n=150)
    for model in (KernelRidge(), StumpBoost(n_stumps=8)):
        res_py = gal.fit(key, make_orgs(xs, model), y, get_loss("mse"),
                         GALConfig(rounds=2, engine="python"))
        res_sc = gal.fit(key, make_orgs(xs, model), y, get_loss("mse"),
                         GALConfig(rounds=2, engine="scan"))
        np.testing.assert_allclose(
            res_sc.history["train_loss"], res_py.history["train_loss"],
            rtol=1e-3, atol=1e-4, err_msg=type(model).__name__)


def test_random_init_models_split_by_width_when_padding_needed(rng_np, key):
    """MLP inits params at the slice width, so padding would change its
    draws: the planner splits unequal widths into per-width groups (the
    grouped engine) instead of falling back, and parity with the reference
    engine holds exactly because each org keeps its true width."""
    from repro.models.zoo import MLP
    xs_unequal, y, _, _ = _setting(rng_np, d=13, n=100)
    res = gal.fit(key, make_orgs(xs_unequal, MLP((8,), epochs=10)), y,
                  get_loss("mse"), GALConfig(rounds=1))
    assert res.engine == "grouped"
    assert res.plan.n_groups == 2           # widths (4,) and (3, 3, 3)
    res_py = gal.fit(key, make_orgs(xs_unequal, MLP((8,), epochs=10)), y,
                     get_loss("mse"), GALConfig(rounds=1, engine="python"))
    np.testing.assert_allclose(res.history["train_loss"],
                               res_py.history["train_loss"],
                               rtol=1e-3, atol=1e-4)
    xs_equal, y2, _, _ = _setting(rng_np, d=12, n=100)
    orgs_equal = make_orgs(xs_equal, MLP((8,), epochs=10))
    expected = "shard" if shard_eligible(orgs_equal) else "scan"
    res2 = gal.fit(key, orgs_equal, y2, get_loss("mse"), GALConfig(rounds=1))
    assert res2.engine == expected


def test_stacked_predict_rejects_mismatched_slices(rng_np, key):
    xs, y, xs_te, _ = _setting(rng_np, d=13)
    res = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                  GALConfig(rounds=2, engine="scan"))
    with pytest.raises(ValueError, match="widths"):
        res.predict(list(reversed(xs_te)))  # wrong org order


def test_heterogeneous_orgs_compile_to_grouped_engine(rng_np, key):
    """Model autonomy no longer means the slow path: a mixed-model org set
    is not scan_compatible (no SINGLE group), but the planner fuses it into
    the grouped engine; forcing the single-group 'scan' engine still raises
    with the planner's group breakdown."""
    xs, y, _, _ = _setting(rng_np)
    models = [Linear(), StumpBoost(n_stumps=10), KernelRidge(), Linear()]
    orgs = make_orgs(xs, models)
    assert not scan_compatible(orgs)
    res = gal.fit(key, orgs, y, get_loss("mse"), GALConfig(rounds=2))
    assert res.engine == "grouped" and res.plan.n_groups == 3
    # interleaved membership: the two Linear orgs share one group
    assert res.plan.groups[0].indices == (0, 3)
    with pytest.raises(ValueError, match="ONE noiseless homogeneous"):
        gal.fit(key, make_orgs(xs, models), y, get_loss("mse"),
                GALConfig(rounds=2, engine="scan"))


def test_dms_and_noise_compile_to_grouped(rng_np, key):
    """Neither DMS nor noisy orgs are fallbacks any more: both break the
    single-group scan contract (scan_compatible False) but compile to the
    grouped engine — DMS through the extractor/stacked-head carry, noise
    through fold_in-derived keys."""
    from repro.models.zoo import MLP
    xs, y, _, _ = _setting(rng_np, n=100)
    dms_orgs = make_orgs(xs, MLP((8,), epochs=5), dms=True)
    assert not scan_compatible(dms_orgs)    # DMS != the single-group contract
    res = gal.fit(key, dms_orgs, y, get_loss("mse"), GALConfig(rounds=1))
    assert res.engine == "grouped"
    assert res.plan.has_dms
    noisy = make_orgs(xs, Linear(), noise_sigmas=[0.1] * 4)
    assert not scan_compatible(noisy)   # noisy != the single-group contract
    res2 = gal.fit(key, noisy, y, get_loss("mse"), GALConfig(rounds=1))
    assert res2.engine == "grouped"


def test_scan_engine_with_privacy_runs(rng_np, key):
    xs, y, _, _ = _setting(rng_np)
    res = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                  GALConfig(rounds=3, privacy="dp", privacy_alpha=5.0,
                            engine="scan"))
    assert res.engine == "scan"
    assert np.isfinite(res.history["train_loss"]).all()


def test_scan_engine_nonuniform_weights_off(rng_np, key):
    res_py, res_sc = _both_engines(
        jax.random.PRNGKey(3), *_setting(np.random.default_rng(3))[:2],
        get_loss("mse"), GALConfig(rounds=3, use_weights=False))
    for w in res_sc.weights:
        np.testing.assert_allclose(np.asarray(w), 0.25, atol=1e-6)
    np.testing.assert_allclose(res_sc.history["train_loss"],
                               res_py.history["train_loss"],
                               rtol=1e-3, atol=1e-4)


def test_lm_engine_parity(key):
    """Fused LM round engine == reference loop (shared smoke architecture)."""
    import math
    from repro.configs import get_arch
    from repro.core import gal_lm
    from repro.data.tokens import make_token_stream, token_batches

    cfg = get_arch("llama3-8b", smoke=True)
    rng_np = np.random.default_rng(0)
    stream = make_token_stream(rng_np, cfg.vocab, 2000)
    toks, labels = next(token_batches(stream, batch=2, seq_len=16, rng=rng_np))
    toks, labels = jnp.asarray(toks), jnp.asarray(labels)
    root = int(math.isqrt(cfg.vocab))

    def mk():
        orgs = [gal_lm.LMOrganization(0, cfg, lambda t: (t // root) % cfg.vocab),
                gal_lm.LMOrganization(1, cfg, lambda t: (t % root) % cfg.vocab)]
        for i, org in enumerate(orgs):
            org.init(jax.random.fold_in(jax.random.PRNGKey(0), i), lr=3e-3)
        return orgs

    res_py = gal_lm.fit_lm(key, mk(), toks, labels, rounds=2, local_steps=3,
                           engine="python")
    res_sc = gal_lm.fit_lm(key, mk(), toks, labels, rounds=2, local_steps=3,
                           engine="scan")
    assert res_py.engine == "python" and res_sc.engine == "scan"
    np.testing.assert_allclose(res_sc.history["train_xent"],
                               res_py.history["train_xent"], rtol=1e-4)
    np.testing.assert_allclose(res_sc.etas, res_py.etas, rtol=1e-3, atol=1e-4)
