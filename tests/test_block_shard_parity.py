"""Block placement (orgs-per-device) and the data mesh axis vs the scan
fast path.

With more organizations than devices the org mesh packs a contiguous block
of B = M / device_count orgs per device; with ``data_shards`` the mesh
gains a second axis splitting each org's N rows. Both distribute the
step-4 assistance-weight fit (per-epoch gradient psums), so unlike the 1:1
placement — whose collectives reproduce the scan engine's arithmetic
bit-for-bit — the block/data paths reassociate floating-point sums inside
100 Adam epochs. The parity tolerances here are the empirically measured
chaos envelope (~1e-2 on etas/weights, <1% on losses), NOT loose bounds:
a placement bug shows up at O(0.1–1), an RNG-discipline bug at O(1).

Within one placement everything stays exact: membership masking, ledgers,
and schedule plumbing are pinned bitwise against the same engine.

Run with REPRO_FORCE_DEVICES=4; on a single device the suite skips.
"""
import dataclasses

import numpy as np
import pytest
import jax

from repro.core import gal
from repro.core.engine import shard_eligible
from repro.core.gal import GALConfig
from repro.core.losses import get_loss
from repro.core.membership import membership_comm_ledger
from repro.core.organizations import make_orgs
from repro.core.protocol_sim import gal_round_bytes
from repro.data.partition import split_features
from repro.data.synthetic import make_regression, train_test_split
from repro.models.zoo import Linear, StumpBoost

D = 4
needs_mesh = pytest.mark.skipif(
    jax.device_count() != D,
    reason=f"block/data placement cells are calibrated for "
           f"REPRO_FORCE_DEVICES={D}")

# chaos envelope: psum reassociation amplified by the distributed weight
# fit's Adam epochs (see module docstring)
ETA_TOL = dict(rtol=0.05, atol=0.05)
HIST_TOL = dict(rtol=0.05, atol=0.01)
W_ATOL = 0.08   # late rounds compound the drift; a real bug shows O(0.3+)


def _setting(rng_np, m, d=None, n=200):
    ds = make_regression(rng_np, n=n, d=d or 3 * m)
    tr, te = train_test_split(ds, rng_np)
    return split_features(tr.x, m), tr.y, split_features(te.x, m), te.y


def _fit(key, xs, y, cfg, model=None, **kw):
    return gal.fit(key, make_orgs(xs, model or Linear()), y,
                   get_loss("mse"), cfg, **kw)


def _assert_parity(res_sc, res_sh):
    np.testing.assert_allclose(res_sh.etas, res_sc.etas, **ETA_TOL)
    np.testing.assert_allclose(np.stack(res_sh.weights),
                               np.stack(res_sc.weights), atol=W_ATOL)
    np.testing.assert_allclose(res_sh.history["train_loss"],
                               res_sc.history["train_loss"], **HIST_TOL)


# -------------------------------------------------------- block placement

@needs_mesh
@pytest.mark.parametrize("m", [8, 16])
def test_block_placement_parity_vs_scan(rng_np, key, m):
    xs, y, xs_te, y_te = _setting(rng_np, m)
    ev = {"test": (xs_te, y_te)}
    cfg = GALConfig(rounds=4)
    res_sc = _fit(key, xs, y, dataclasses.replace(cfg, engine="scan"),
                  eval_sets=ev)
    res_sh = _fit(key, xs, y, dataclasses.replace(cfg, engine="shard"),
                  eval_sets=ev)
    assert res_sh.engine == "shard"
    _assert_parity(res_sc, res_sh)
    np.testing.assert_allclose(res_sh.history["test_loss"],
                               res_sc.history["test_loss"], **HIST_TOL)
    # per-round params keep the scan path's stacked (T, M, ...) contract
    leaves = jax.tree_util.tree_leaves(res_sh.stacked_params)
    assert all(l.shape[:2] == (4, m) for l in leaves)


@needs_mesh
def test_auto_prefers_shard_for_block_eligible_orgs(rng_np, key):
    xs, y, _, _ = _setting(rng_np, 8)
    orgs = make_orgs(xs, Linear())
    assert shard_eligible(orgs)
    res = gal.fit(key, orgs, y, get_loss("mse"), GALConfig(rounds=2))
    assert res.engine == "shard"


@needs_mesh
def test_block_predictions_track_scan(rng_np, key):
    xs, y, xs_te, _ = _setting(rng_np, 8)
    res_sc = _fit(key, xs, y, GALConfig(rounds=4, engine="scan"))
    res_sh = _fit(key, xs, y, GALConfig(rounds=4, engine="shard"))
    p_sc = np.asarray(res_sc.predict(xs_te))
    p_sh = np.asarray(res_sh.predict(xs_te))
    np.testing.assert_allclose(p_sh, p_sc, rtol=0.1, atol=0.15)


@needs_mesh
def test_block_ledger_is_engine_independent(rng_np, key):
    xs, y, xs_te, y_te = _setting(rng_np, 8)
    ev = {"test": (xs_te, y_te)}
    res_sc = _fit(key, xs, y, GALConfig(rounds=3, engine="scan"),
                  eval_sets=ev)
    res_sh = _fit(key, xs, y, GALConfig(rounds=3, engine="shard"),
                  eval_sets=ev)
    b, g = gal_round_bytes(y.shape[0], y.shape[-1], 8,
                           eval_ns=(y_te.shape[0],))
    assert res_sh.history["comm_broadcast_bytes"] == [b] * 3 == \
        res_sc.history["comm_broadcast_bytes"]
    assert res_sh.history["comm_gather_bytes"] == [g] * 3 == \
        res_sc.history["comm_gather_bytes"]


# ------------------------------------------------- bf16 conformance cell

@needs_mesh
def test_bf16_toggle_under_block_placement(rng_np, key):
    """Compression composes with block placement: parity vs the scan
    engine's bf16 run holds to the same chaos envelope, and the ledger
    halves the broadcast exactly."""
    xs, y, _, _ = _setting(rng_np, 8)
    cfg16 = GALConfig(rounds=4, residual_dtype="bf16")
    res_sc = _fit(key, xs, y, dataclasses.replace(cfg16, engine="scan"))
    res_sh = _fit(key, xs, y, dataclasses.replace(cfg16, engine="shard"))
    _assert_parity(res_sc, res_sh)
    res_32 = _fit(key, xs, y, GALConfig(rounds=4, engine="shard"))
    assert [b * 2 for b in res_sh.history["comm_broadcast_bytes"]] == \
        res_32.history["comm_broadcast_bytes"]
    assert res_sh.history["comm_gather_bytes"] == \
        res_32.history["comm_gather_bytes"]


# --------------------------------------------------- membership / contrib

@needs_mesh
def test_block_membership_explicit_all_live_is_bitwise_noop(rng_np, key):
    xs, y, _, _ = _setting(rng_np, 8)
    res_none = _fit(key, xs, y, GALConfig(rounds=3, engine="shard"))
    res_live = _fit(key, xs, y, GALConfig(rounds=3, engine="shard"),
                    membership=np.ones((3, 8), bool))
    assert res_none.etas == res_live.etas
    assert res_none.history["train_loss"] == res_live.history["train_loss"]
    assert np.array_equal(np.stack(res_none.weights),
                          np.stack(res_live.weights))


@needs_mesh
def test_block_membership_masks_weights_and_ledger(rng_np, key):
    """An org absent in round t gets weight exactly 0.0 there and drops out
    of that round's ledger — under block placement too."""
    m, rounds = 8, 3
    xs, y, _, _ = _setting(rng_np, m)
    sched = np.ones((rounds, m), bool)
    sched[1, 2] = False
    sched[2, 5] = False
    res = _fit(key, xs, y, GALConfig(rounds=rounds, engine="shard"),
               membership=sched)
    assert res.engine == "shard"
    w = np.stack(res.weights)
    assert w[1, 2] == 0.0 and w[2, 5] == 0.0
    assert (w[0] > 0).all()
    eb, eg = membership_comm_ledger(sched, y.shape[0], y.shape[-1])
    assert res.history["comm_broadcast_bytes"] == eb
    assert res.history["comm_gather_bytes"] == eg


@needs_mesh
def test_block_straggler_sim_is_deterministic(rng_np, key):
    xs, y, _, _ = _setting(rng_np, 8)
    cfg = GALConfig(rounds=3, engine="shard", straggler_sim=0.3,
                    straggler_seed=7)
    r1 = _fit(key, xs, y, cfg)
    r2 = _fit(key, xs, y, cfg)
    assert r1.etas == r2.etas
    assert r1.history["comm_broadcast_bytes"] == \
        r2.history["comm_broadcast_bytes"]


# ----------------------------------------------------------- data axis

@needs_mesh
@pytest.mark.parametrize("m", [2, 4])
def test_data_axis_parity_vs_scan(rng_np, key, m):
    """data_shards=2 on 4 devices: m=2 is 1:1 x data, m=4 is block x data
    (both mesh axes live). The per-round weight fit and eta line search
    reduce across the data axis."""
    xs, y, xs_te, y_te = _setting(rng_np, m, d=12)
    ev = {"test": (xs_te, y_te)}
    cfg = GALConfig(rounds=4, data_shards=2)
    res_sc = _fit(key, xs, y, GALConfig(rounds=4, engine="scan"),
                  eval_sets=ev)
    res_sh = _fit(key, xs, y, dataclasses.replace(cfg, engine="shard"),
                  eval_sets=ev)
    assert res_sh.engine == "shard"
    _assert_parity(res_sc, res_sh)
    # the ledger is a wire-protocol property: slicing rows across devices
    # does not change what crosses org boundaries
    assert res_sh.history["comm_broadcast_bytes"] == \
        res_sc.history["comm_broadcast_bytes"]


@needs_mesh
def test_data_axis_rejects_privacy(rng_np, key):
    xs, y, _, _ = _setting(rng_np, 2, d=12)
    with pytest.raises(ValueError, match="privat"):
        _fit(key, xs, y, GALConfig(rounds=1, engine="shard", data_shards=2,
                                   privacy="dp"))


@needs_mesh
def test_data_axis_rejects_non_data_parallel_model(rng_np, key):
    xs, y, _, _ = _setting(rng_np, 2, d=12)
    with pytest.raises(ValueError, match="data_parallel"):
        _fit(key, xs, y, GALConfig(rounds=1, engine="shard", data_shards=2),
             model=StumpBoost())


@needs_mesh
def test_data_axis_rejects_indivisible_rows(rng_np, key):
    xs, y, _, _ = _setting(rng_np, 2, d=12, n=200)
    n = y.shape[0]
    xs = [x[: n - 1] for x in xs]
    with pytest.raises(ValueError):
        _fit(key, xs, y[: n - 1],
             GALConfig(rounds=1, engine="shard", data_shards=2))


def test_data_shards_validation_is_engine_gated(rng_np, key):
    """Runs in ANY device configuration: data_shards > 1 demands the shard
    engine (or auto resolving to it); the scan engine must refuse."""
    xs, y, _, _ = _setting(rng_np, 2, d=12)
    with pytest.raises(ValueError, match="data_shards"):
        _fit(key, xs, y, GALConfig(rounds=1, engine="scan", data_shards=2))
    with pytest.raises(ValueError, match="data_shards"):
        _fit(key, xs, y, GALConfig(rounds=1, data_shards=0))
