"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
pytest.importorskip("hypothesis")  # optional dev dep; skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core.losses import (
    BCELoss, CrossEntropyLoss, MAELoss, MSELoss, lq_loss,
)
from repro.core.weights import fit_weights
from repro.core.protocol_sim import al_cost, gal_cost
from repro.optim.lbfgs import golden_section, line_search, scalar_lbfgs


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 64), k=st.integers(1, 8), seed=st.integers(0, 999))
def test_residual_is_negative_gradient(n, k, seed):
    """r = -dL/dF for every loss (the definition in Alg. 1)."""
    key = jax.random.PRNGKey(seed)
    f = jax.random.normal(key, (n, k))
    for loss in (MSELoss(), CrossEntropyLoss()):
        if isinstance(loss, CrossEntropyLoss):
            y = jax.nn.one_hot(jax.random.randint(key, (n,), 0, k), k)
        else:
            y = jax.random.normal(jax.random.fold_in(key, 1), (n, k))
        analytic = loss.residual(y, f)
        autodiff = -jax.grad(lambda ff: jnp.sum(loss.per_sample(y, ff)))(f)
        np.testing.assert_allclose(np.asarray(analytic), np.asarray(autodiff),
                                   atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 999), m=st.integers(2, 6))
def test_weights_live_on_simplex(seed, m):
    key = jax.random.PRNGKey(seed)
    r = jax.random.normal(key, (32, 3))
    preds = jax.random.normal(jax.random.fold_in(key, 1), (m, 32, 3))
    w = fit_weights(key, r, preds, lq_loss(2.0), epochs=20)
    w = np.asarray(w)
    assert np.all(w >= 0)
    np.testing.assert_allclose(w.sum(), 1.0, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(a=st.floats(-5, 5), b=st.floats(0.1, 10.0))
def test_scalar_minimizers_find_quadratic_minimum(a, b):
    fn = lambda x: b * (x - a) ** 2 + 1.0
    for result in (scalar_lbfgs(fn, x0=0.5), ):
        assert abs(float(result) - a) < 0.05, (float(result), a)
    g = golden_section(fn, a - 3, a + 3, iters=50)
    assert abs(float(g) - a) < 0.01


@settings(max_examples=15, deadline=None)
@given(n=st.integers(8, 256), k=st.integers(1, 32), m=st.integers(2, 12),
       rounds=st.integers(1, 20))
def test_protocol_complexity_relations(n, k, m, rounds):
    """Paper Table 14: AL costs M x the communication rounds and sequential
    fits of GAL at equal ensemble size."""
    g = gal_cost(n, k, m, rounds)
    a = al_cost(n, k, m, rounds)
    assert a.ensemble_members == g.ensemble_members
    assert a.comm_rounds == m * g.comm_rounds
    assert a.sequential_fits == m * g.sequential_fits
    assert g.bytes_broadcast < a.bytes_broadcast


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 99), q=st.sampled_from([1.0, 1.5, 2.0, 4.0]))
def test_lq_loss_nonnegative_and_zero_at_fit(seed, q):
    key = jax.random.PRNGKey(seed)
    r = jax.random.normal(key, (16, 4))
    assert float(lq_loss(q)(r, r)) < 1e-6
    f = r + 0.5
    assert float(lq_loss(q)(r, f)) > 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 99))
def test_bce_residual_bounded(seed):
    key = jax.random.PRNGKey(seed)
    y = (jax.random.uniform(key, (32, 1)) > 0.5).astype(jnp.float32)
    f = jax.random.normal(jax.random.fold_in(key, 1), (32, 1)) * 4
    r = BCELoss().residual(y, f)
    assert float(jnp.max(jnp.abs(r))) <= 1.0


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 99), b=st.integers(1, 3), s=st.integers(2, 24))
def test_moe_capacity_preserves_token_mass(seed, b, s):
    """Dropped-token gates are zeroed; kept gates renormalized <= 1."""
    from repro.configs import get_arch
    from repro.models.moe import apply_moe, init_moe
    cfg = get_arch("phi3.5-moe-42b-a6.6b", smoke=True)
    key = jax.random.PRNGKey(seed)
    params = init_moe(key, cfg)
    x = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    y, aux = apply_moe(params, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(float(aux))
    assert not bool(jnp.any(jnp.isnan(y)))
