"""The paper's Fig. 4 validation protocol, for every engine.

During ``gal.fit`` the eval sets are scored each round with the
*prediction-stage* mechanics, so the recorded per-round curve must be
reproducible after the fact: for every round t,

    loss(y_eval, result.predict(xs_eval, rounds=t)) == history["eval_loss"][t]

(index 0 is the F^0 initializer entry). This pins the contract across the
python / scan / grouped engines (the shard engine is covered by the same
check in tests/test_shard_parity.py under REPRO_FORCE_DEVICES), including
early-stopped fits where the history is trimmed, and noisy organizations
where both sides must draw the identical prediction-stage noise.
"""
import numpy as np
import pytest

from repro.core import gal
from repro.core.gal import GALConfig
from repro.core.losses import get_loss
from repro.core.organizations import make_orgs
from repro.data.partition import split_features
from repro.data.synthetic import make_blobs, make_regression, train_test_split
from repro.models.zoo import KernelRidge, Linear, StumpBoost


def _setting(rng_np, m=4, d=12, n=200):
    ds = make_regression(rng_np, n=n, d=d)
    tr, te = train_test_split(ds, rng_np)
    return split_features(tr.x, m), tr.y, split_features(te.x, m), te.y


def _check_fig4(res, loss, xs_te, y_te, rtol=1e-4, atol=1e-5):
    curve = res.history["test_loss"]
    assert len(curve) == res.rounds + 1
    for t in range(res.rounds + 1):
        replay = float(loss(y_te, res.predict(xs_te, rounds=t)))
        np.testing.assert_allclose(replay, curve[t], rtol=rtol, atol=atol,
                                   err_msg=f"round {t} ({res.engine})")


@pytest.mark.parametrize("engine", ["python", "scan", "grouped"])
def test_predict_rounds_reproduces_eval_history(rng_np, key, engine):
    xs, y, xs_te, y_te = _setting(rng_np)
    loss = get_loss("mse")
    res = gal.fit(key, make_orgs(xs, Linear()), y, loss,
                  GALConfig(rounds=4, engine=engine),
                  eval_sets={"test": (xs_te, y_te)})
    assert res.engine == engine
    _check_fig4(res, loss, xs_te, y_te)


@pytest.mark.parametrize("engine", ["python", "grouped"])
def test_fig4_protocol_on_model_autonomy_mix(rng_np, key, engine):
    xs, y, xs_te, y_te = _setting(rng_np)
    models = [StumpBoost(n_stumps=8), KernelRidge(),
              StumpBoost(n_stumps=8), KernelRidge()]
    loss = get_loss("mse")
    res = gal.fit(key, make_orgs(xs, models), y, loss,
                  GALConfig(rounds=3, engine=engine),
                  eval_sets={"test": (xs_te, y_te)})
    _check_fig4(res, loss, xs_te, y_te)


@pytest.mark.parametrize("engine", ["python", "grouped"])
def test_fig4_protocol_on_noisy_orgs(rng_np, key, engine):
    """The replay only works because prediction-stage noise keys are
    engine-independent (fold_in(PRNGKey(index), t)): predict(rounds=t) must
    re-draw the exact noise the in-fit eval evaluation drew."""
    xs, y, xs_te, y_te = _setting(rng_np)
    loss = get_loss("mse")
    res = gal.fit(key, make_orgs(xs, Linear(),
                                 noise_sigmas=[0.0, 1.0, 0.0, 1.0]),
                  y, loss, GALConfig(rounds=3, engine=engine),
                  eval_sets={"test": (xs_te, y_te)})
    _check_fig4(res, loss, xs_te, y_te)


@pytest.mark.parametrize("engine", ["python", "scan"])
def test_fig4_protocol_survives_early_stop(rng_np, key, engine):
    """Early stopping trims the history; the remaining prefix must still
    replay exactly through predict(rounds=t)."""
    xs, y, xs_te, y_te = _setting(rng_np)
    loss = get_loss("mse")
    res = gal.fit(key, make_orgs(xs, Linear()), y, loss,
                  GALConfig(rounds=10, eta_stop_threshold=10.0,
                            engine=engine),
                  eval_sets={"test": (xs_te, y_te)})
    assert res.rounds < 10
    _check_fig4(res, loss, xs_te, y_te)


def test_fig4_protocol_classification(rng_np, key):
    ds = make_blobs(rng_np, n=150, d=10, k=5)
    tr, te = train_test_split(ds, rng_np)
    xs, xs_te = split_features(tr.x, 4), split_features(te.x, 4)
    loss = get_loss("xent")
    for engine in ("python", "scan"):
        res = gal.fit(key, make_orgs(xs, Linear()), tr.y, loss,
                      GALConfig(rounds=3, engine=engine),
                      eval_sets={"test": (xs_te, te.y)})
        _check_fig4(res, loss, xs_te, te.y, rtol=1e-3, atol=1e-4)
