# Must run before the first jax operation in the test process: the
# shard-engine parity suite is exercised with REPRO_FORCE_DEVICES=4, which
# splits the host CPU into N virtual devices.
from repro.utils.force_devices import apply_force_devices
apply_force_devices()

import numpy as np
import pytest
import jax


@pytest.fixture
def rng_np():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches():
    """XLA-CPU's JIT accumulates dylib symbols across hundreds of
    compilations and eventually fails with 'Failed to materialize symbols'
    in long single-process runs; clearing compiled-function caches between
    test modules keeps the full suite stable."""
    yield
    jax.clear_caches()
