"""Hypothesis property sweeps for the Pallas kernels (moved out of
tests/test_kernels.py so the deterministic kernel suite runs without the
optional dev dep, matching the repo's importorskip pattern)."""
import numpy as np
import pytest
import jax
pytest.importorskip("hypothesis")  # optional dev dep; skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.ops import flash_attention, residual_xent


@settings(max_examples=12, deadline=None)
@given(
    t=st.integers(1, 200),
    v=st.integers(2, 700),
    scale=st.floats(0.1, 8.0),
)
def test_residual_xent_property(t, v, scale):
    """Property: r = onehot - softmax for arbitrary shapes/scales."""
    key = jax.random.PRNGKey(t * 1000 + v)
    logits = jax.random.normal(key, (t, v)) * scale
    labels = jax.random.randint(key, (t,), 0, v)
    out = residual_xent(logits, labels)
    want = ref.residual_xent_ref(logits, labels)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(
    s=st.integers(2, 160),
    h_pow=st.integers(0, 3),
    g=st.sampled_from([1, 2, 4]),
    causal=st.booleans(),
)
def test_flash_attention_property(s, h_pow, g, causal):
    kv = 2 ** h_pow
    h = kv * g
    hd = 32
    key = jax.random.PRNGKey(s * 31 + h)
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, s, h, hd)) * 0.3
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, s, kv, hd)) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 3), (1, s, kv, hd))
    out = flash_attention(q, k, v, causal=causal)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
