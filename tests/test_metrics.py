"""Metric correctness, esp. AUROC under tied scores (quantized logits)."""
import numpy as np
import jax.numpy as jnp

from repro.metrics.metrics import accuracy, auroc, mad


def test_auroc_hand_computed_tied_case():
    """Exact average tied ranks, checked against hand-counted pairs:
    pos scores {0.35, 0.8, 0.4} vs neg {0.1, 0.4, 0.4} ->
    wins 1+3+1, ties 2x0.5 -> U = 6 of 9 pairs -> AUROC = 2/3."""
    y = jnp.asarray([0., 0., 1., 1., 1., 0.]).reshape(-1, 1)
    s = jnp.asarray([0.1, 0.4, 0.35, 0.8, 0.4, 0.4]).reshape(-1, 1)
    np.testing.assert_allclose(float(auroc(y, s)), 6.0 / 9.0, rtol=1e-6)


def test_auroc_order_independent_under_ties():
    """Pre-fix, bare argsort ranks made tied AUROC depend on sample order."""
    rng = np.random.default_rng(0)
    y = (rng.random(64) > 0.5).astype(np.float32)
    s = np.round(rng.normal(size=64), 1)  # quantized -> many ties
    base = float(auroc(jnp.asarray(y), jnp.asarray(s)))
    for seed in range(5):
        perm = np.random.default_rng(seed).permutation(64)
        got = float(auroc(jnp.asarray(y[perm]), jnp.asarray(s[perm])))
        np.testing.assert_allclose(got, base, rtol=1e-6)


def test_auroc_all_ties_is_chance():
    y = jnp.asarray([1., 0., 1., 0.])
    s = jnp.ones((4,))
    np.testing.assert_allclose(float(auroc(y, s)), 0.5, atol=1e-6)


def test_auroc_perfect_and_inverted_separation():
    y = jnp.asarray([0., 0., 1., 1.])
    s = jnp.asarray([-2., -1., 1., 2.])
    assert float(auroc(y, s)) == 1.0
    assert float(auroc(y, -s)) == 0.0


def test_auroc_degenerate_single_class():
    y = jnp.zeros((4,))
    assert float(auroc(y, jnp.arange(4.0))) == 0.5


def test_accuracy_and_mad_smoke():
    y = jnp.asarray([[1., 0.], [0., 1.]])
    assert float(accuracy(y, jnp.asarray([[2., 1.], [0., 3.]]))) == 100.0
    np.testing.assert_allclose(
        float(mad(jnp.zeros((3, 1)), jnp.ones((3, 1)))), 1.0)
