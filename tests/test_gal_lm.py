"""GAL at LM scale: protocol over assigned-architecture organizations."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import gal_lm
from repro.data.tokens import make_token_stream, token_batches


def _views(vocab):
    """Vocab-factorized vertical split: org0 sees high bits, org1 low bits."""
    import math
    root = int(math.isqrt(vocab))

    def view_hi(tokens):
        return (tokens // root) % vocab

    def view_lo(tokens):
        return (tokens % root) % vocab

    return view_hi, view_lo


def test_gal_lm_two_orgs_decrease_xent(key):
    cfg = get_arch("llama3-8b", smoke=True)
    rng_np = np.random.default_rng(0)
    stream = make_token_stream(rng_np, cfg.vocab, 4000)
    toks, labels = next(token_batches(stream, batch=4, seq_len=32,
                                      rng=rng_np))
    toks, labels = jnp.asarray(toks), jnp.asarray(labels)
    hi, lo = _views(cfg.vocab)
    orgs = [
        gal_lm.LMOrganization(0, cfg, hi),
        gal_lm.LMOrganization(1, cfg, lo),
    ]
    for i, org in enumerate(orgs):
        org.init(jax.random.fold_in(key, i), lr=3e-3)
    res = gal_lm.fit_lm(key, orgs, toks, labels, rounds=2, local_steps=8)
    hist = res.history["train_xent"]
    assert hist[-1] < hist[0], hist
    assert len(res.etas) == 2
    for w in res.weights:
        np.testing.assert_allclose(float(jnp.sum(w)), 1.0, atol=1e-5)


def test_residual_kernel_in_protocol(key):
    """Pseudo-residual via the Pallas kernel == jnp path inside fit_lm."""
    labels = jax.random.randint(key, (2, 8), 0, 300)
    logits = jax.random.normal(key, (2, 8, 300)) * 2
    r_kernel = gal_lm.compute_residual(labels, logits, use_kernel=True)
    r_ref = gal_lm.compute_residual(labels, logits, use_kernel=False)
    np.testing.assert_allclose(np.asarray(r_kernel), np.asarray(r_ref),
                               atol=1e-5)


def test_topk_compression_concentration(key):
    """GAL residuals are concentrated: top-64 keeps nearly all mass."""
    labels = jax.random.randint(key, (128,), 0, 4096)
    logits = jax.random.normal(key, (128, 4096)) * 2.0
    r = gal_lm.compute_residual(labels[None], logits[None],
                                use_kernel=False)[0]
    vals, idx = gal_lm.topk_compress(r, 64)
    mass = jnp.sum(jnp.square(vals)) / jnp.sum(jnp.square(r))
    assert float(mass) > 0.95


def test_topk_loss_matches_dense_loss(key):
    """gal_residual_topk == gal_residual when the residual is exactly
    K-sparse (the exactness claim in steps.py)."""
    from repro.configs import get_arch
    from repro.models import transformer as tfm
    from repro.train.steps import gal_residual_loss, gal_residual_topk_loss
    cfg = get_arch("qwen3-1.7b", smoke=True)
    params = tfm.init_params(key, cfg)
    b, s, k = 2, 16, 8
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    idx = jnp.tile(jnp.arange(k)[None, None], (b, s, 1)).astype(jnp.int32)
    vals = jax.random.normal(key, (b, s, k), jnp.float32)
    dense = jnp.zeros((b, s, cfg.vocab)).at[..., :k].set(vals)
    l_dense, _ = gal_residual_loss(
        params, cfg, {"tokens": tokens, "residual": dense})
    l_topk, _ = gal_residual_topk_loss(
        params, cfg, {"tokens": tokens, "residual_idx": idx,
                      "residual_vals": vals})
    np.testing.assert_allclose(float(l_dense), float(l_topk), rtol=2e-2)
