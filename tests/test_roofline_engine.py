"""Roofline vs the real shard engine: the collective traffic XLA compiles
for a GAL fit must reconcile — in exact integers — with both the analytic
expectation (``gal_shard_round_collectives``) and the protocol ledger
(``gal_round_bytes``).

The HLO-facing tests compile the actual ``lower_shard_round`` program in a
subprocess with 4 forced host devices (jax pins the device count at first
init, so the main test process must stay at 1 device) and ship the parsed
per-kind byte counts back as JSON.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core.protocol_sim import gal_round_bytes
from repro.roofline.analysis import gal_shard_round_collectives


# ---------------------------------------------------------------- unit level

def test_helper_reconciles_with_ledger_train_gather():
    n, k, m, rounds, ne = 128, 3, 8, 5, (32, 16)
    for ds in (1, 2):
        exp = gal_shard_round_collectives(n, k, m, rounds, eval_ns=ne,
                                          data_shards=ds,
                                          block_size=2)
        b, g = gal_round_bytes(n, k, m, eval_ns=ne)
        # the gathered (M, N/ds, K) tensor is the ledger's train-set gather
        # counted once per data shard; eval stages ride the ledger only
        train_gather = rounds * m * n * k * 4
        assert ds * exp["all_gather"] == train_gather
        assert rounds * (b + g) >= exp["all_gather"]


def test_helper_reconciles_with_ledger_broadcast():
    n, k, m, rounds = 200, 1, 16, 7
    exp = gal_shard_round_collectives(n, k, m, rounds, block_size=4)
    b, _ = gal_round_bytes(n, k, m)
    # one psum serves all M-1 receivers: ledger counts per-link copies
    assert rounds * b == (m - 1) * exp["all_reduce_broadcast"]


def test_bf16_halves_ledger_not_simulated_collectives():
    """residual_dtype="bf16" is a wire-protocol property: the ledger's
    broadcast halves exactly, while the compiled mesh's psum stays f32
    (XLA folds the upcast into the all-reduce producer)."""
    n, k, m = 512, 2, 8
    b32, g32 = gal_round_bytes(n, k, m, eval_ns=(64,))
    b16, g16 = gal_round_bytes(n, k, m, eval_ns=(64,), resid_dtype_bytes=2)
    assert b16 * 2 == b32
    assert g16 == g32
    exp = gal_shard_round_collectives(n, k, m, rounds=3, eval_ns=(64,))
    # no dtype knob on the helper at all — simulated traffic is dtype-blind
    assert exp["all_reduce_broadcast"] == 3 * n * k * 4


def test_helper_weight_fit_term_zero_iff_replicated():
    n, k, m = 128, 1, 4
    rep = gal_shard_round_collectives(n, k, m, rounds=2, block_size=1)
    blk = gal_shard_round_collectives(n, k, m, rounds=2, block_size=2)
    assert rep["all_reduce_weight_fit"] == 0 and rep["all_reduce_exact"]
    assert blk["all_reduce_weight_fit"] > 0
    dat = gal_shard_round_collectives(n, k, m, rounds=2, data_shards=2)
    assert dat["all_reduce_weight_fit"] > 0 and not dat["all_reduce_exact"]


def test_helper_validates_data_shards():
    with pytest.raises(ValueError):
        gal_shard_round_collectives(100, 1, 4, 2, data_shards=3)


# ----------------------------------------------------------- compiled level

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["REPRO_FORCE_DEVICES"] = "4"
    from repro.utils.force_devices import apply_force_devices
    apply_force_devices()
    import json
    import numpy as np
    import jax

    from repro.core.engine import lower_shard_round
    from repro.core.gal import GALConfig
    from repro.core.losses import get_loss
    from repro.core.organizations import make_orgs
    from repro.data.partition import split_features
    from repro.data.synthetic import make_regression, train_test_split
    from repro.models.zoo import Linear
    from repro.roofline.analysis import collective_bytes_from_hlo
    from repro.roofline.hlo_stats import analyze

    rng_np = np.random.default_rng(0)
    ds = make_regression(rng_np, n=160, d=24)
    tr, te = train_test_split(ds, rng_np)
    loss = get_loss("mse")
    key = jax.random.PRNGKey(0)
    out = {"n": int(tr.y.shape[0]), "ne": int(te.y.shape[0]),
           "k": int(tr.y.shape[-1]), "cells": {}}
    CELLS = {
        "one_to_one": dict(m=4, data_shards=1),
        "block": dict(m=8, data_shards=1),
        "data_axis": dict(m=2, data_shards=2),
    }
    for tag, cell in CELLS.items():
        cfg = GALConfig(rounds=3, engine="shard", weight_epochs=5,
                        data_shards=cell["data_shards"])
        xs = split_features(tr.x, cell["m"])
        evs = {"test": (split_features(te.x, cell["m"]), te.y)}
        low = lower_shard_round(key, make_orgs(xs, Linear()), tr.y, loss,
                                cfg, eval_sets=evs)
        txt = low.compile().as_text()
        st = analyze(txt)
        out["cells"][tag] = {
            "m": cell["m"], "data_shards": cell["data_shards"],
            "rounds": 3, "weight_epochs": 5,
            "analyze": {kk: int(v) for kk, v in st.collectives.items()},
            "flat": collective_bytes_from_hlo(txt),
        }
    print("RESULT:" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def hlo_cells():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("REPRO_FORCE_DEVICES", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


@pytest.mark.slow
def test_compiled_collectives_match_helper_exactly(hlo_cells):
    """1:1 and block placement on an un-sharded data axis: every compiled
    collective byte is accounted for, kind by kind."""
    n, ne, k = hlo_cells["n"], hlo_cells["ne"], hlo_cells["k"]
    for tag in ("one_to_one", "block"):
        cell = hlo_cells["cells"][tag]
        m = cell["m"]
        exp = gal_shard_round_collectives(
            n, k, m, cell["rounds"], eval_ns=(ne,),
            weight_epochs=cell["weight_epochs"],
            block_size=m // 4, data_shards=1)
        assert exp["all_reduce_exact"]
        got = cell["analyze"]
        assert got["all-gather"] == exp["all_gather"], tag
        assert got["all-reduce"] == exp["all_reduce"], tag
        assert set(got) == {"all-gather", "all-reduce"}, tag


@pytest.mark.slow
def test_compiled_collectives_match_ledger_ints(hlo_cells):
    """The protocol ledger's exact ints reconcile with the compiled HLO:
    train-set gather is the all-gather tensor once per data shard, the
    broadcast is one psum serving M-1 ledger links."""
    n, ne, k = hlo_cells["n"], hlo_cells["ne"], hlo_cells["k"]
    for tag, cell in hlo_cells["cells"].items():
        m, ds, rounds = cell["m"], cell["data_shards"], cell["rounds"]
        bcast, gathered = gal_round_bytes(n, k, m, eval_ns=(ne,))
        exp = gal_shard_round_collectives(
            n, k, m, rounds, eval_ns=(ne,),
            weight_epochs=cell["weight_epochs"],
            block_size=max(m // (4 // ds), 1), data_shards=ds)
        got = cell["analyze"]
        # ledger train gather (without the eval prediction stage, which the
        # mesh ships as weighted-sum all-reduces instead of per-org rows)
        assert rounds * m * n * k * 4 == ds * got["all-gather"], tag
        assert rounds * bcast == (m - 1) * ds * exp["all_reduce_broadcast"], tag
        # all_reduce is exact on ds=1, a lower bound under a data axis
        if exp["all_reduce_exact"]:
            assert got["all-reduce"] == exp["all_reduce"], tag
        else:
            assert got["all-reduce"] >= exp["all_reduce"], tag


@pytest.mark.slow
def test_flat_parse_agrees_with_loop_aware_parse(hlo_cells):
    """collective_bytes_from_hlo (no trip counts) vs hlo_stats.analyze
    (trip-count-multiplied): the round scan multiplies the all-gather by
    exactly ``rounds``."""
    for tag, cell in hlo_cells["cells"].items():
        assert cell["analyze"]["all-gather"] == \
            cell["rounds"] * cell["flat"]["all-gather"], tag
