"""Dynamic-membership conformance: dropout, stragglers, and mid-fit joins.

The counterfactual harness this PR pins:

* a membership-scheduled fit matches the Python oracle on every engine
  that can run it — etas, renormalized weights (absent orgs EXACTLY 0.0),
  every history column including the per-round communication/memory
  ledgers, the recorded membership matrix, and predict at every prefix;
* a fit with org j masked out of every round is BITWISE equal to fitting
  the reduced org set without j — the counterfactual parity that makes
  ``repro.core.contrib`` exact;
* a mid-fit join (resume onto a grown org set) is BITWISE equal to a
  fresh fit of the grown set whose schedule masks the joiners before the
  join round — and the leave-one-out refit-from-carry shortcut is BITWISE
  equal to the same counterfactual fit from scratch;
* the fault-injection knobs (``GALConfig.straggler_sim``) are seeded,
  deterministic, never produce an empty round, and compose (AND) with an
  explicit schedule;
* schedules that cannot run (wrong shape, non-boolean, empty rounds) and
  growths that cannot resume (DMS joins, position/id collisions,
  straggler_sim across a growth) raise up front with the specific reason.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import gal
from repro.core.gal import GALConfig
from repro.core.losses import get_loss
from repro.core.membership import (membership_comm_ledger,
                                   resolve_membership, straggler_schedule)
from repro.core.organizations import make_orgs
from repro.core.protocol_sim import gal_model_memories, gal_round_bytes
from repro.data.partition import split_features
from repro.data.synthetic import make_regression, train_test_split
from repro.launch.mesh import org_mesh_eligible
from repro.models.zoo import KernelRidge, Linear, MLP, StumpBoost

M = 4
ROUNDS = 3

# org 2 skips round 1, org 0 skips round 2 — exercises dropout mid-fit
# and a round where the weight fit renormalizes over 3 live orgs
SCHED = np.ones((ROUNDS, M), bool)
SCHED[1, 2] = False
SCHED[2, 0] = False


def _data():
    rng_np = np.random.default_rng(7)
    ds = make_regression(rng_np, n=160, d=12)
    tr, te = train_test_split(ds, rng_np)
    return (split_features(tr.x, M), tr.y,
            split_features(te.x, M), te.y)


SCENARIOS = {
    "dropout_homog": dict(
        orgs=lambda xs: make_orgs(xs, Linear()),
        cfg={}, membership=SCHED, extra_engines=("scan", "shard")),
    "dropout_hetero": dict(
        orgs=lambda xs: make_orgs(
            xs, [StumpBoost(n_stumps=8) if i % 2 == 0 else KernelRidge()
                 for i in range(M)]),
        cfg={}, membership=SCHED, extra_engines=()),
    "dropout_dms": dict(
        orgs=lambda xs: make_orgs(xs, MLP((8,), epochs=5), dms=True),
        cfg={}, membership=SCHED, extra_engines=()),
    "straggler": dict(
        orgs=lambda xs: make_orgs(xs, Linear()),
        cfg={"straggler_sim": 0.35, "straggler_seed": 3},
        membership=None, extra_engines=("scan", "shard")),
}

_CELLS = [(s, e) for s, spec in SCENARIOS.items()
          for e in ("grouped",) + spec["extra_engines"]]

_ORACLE_CACHE = {}


def _fit(scenario, engine, key):
    xs, y, xs_te, y_te = _data()
    spec = SCENARIOS[scenario]
    cfg = GALConfig(**{"rounds": ROUNDS, "engine": engine, **spec["cfg"]})
    return gal.fit(key, spec["orgs"](xs), y, get_loss("mse"), cfg,
                   eval_sets={"test": (xs_te, y_te)}, metrics=("mad",),
                   membership=spec["membership"])


def _oracle(scenario, key):
    if scenario not in _ORACLE_CACHE:
        _ORACLE_CACHE[scenario] = _fit(scenario, "python", key)
    return _ORACLE_CACHE[scenario]


def _expected_sched(scenario):
    spec = SCENARIOS[scenario]
    return resolve_membership(spec["membership"],
                              spec["cfg"].get("straggler_sim"),
                              spec["cfg"].get("straggler_seed", 0),
                              ROUNDS, M)


@pytest.mark.parametrize("scenario,engine", _CELLS,
                         ids=[f"{s}-{e}" for s, e in _CELLS])
def test_membership_engine_matches_python_oracle(key, scenario, engine):
    """The full conformance contract of test_conformance.py, under a
    membership schedule: every engine agrees with the oracle AND pins the
    membership-specific quantities (exact-zero weights for absent orgs,
    the reduced per-round ledgers, the recorded schedule)."""
    if engine == "shard" and not org_mesh_eligible(M):
        pytest.skip(f"no org mesh for {M} orgs on "
                    f"{len(jnp.zeros(1).devices())} device(s) "
                    f"(run under REPRO_FORCE_DEVICES={M})")
    res_py = _oracle(scenario, key)
    res = _fit(scenario, engine, key)
    sched = _expected_sched(scenario)
    assert res.engine == engine

    assert res.rounds == res_py.rounds
    np.testing.assert_allclose(res.etas, res_py.etas, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.stack(res.weights),
                               np.stack(res_py.weights), atol=1e-3)
    # absent orgs carry weight EXACTLY 0.0; live weights renormalize to 1
    for t in range(res.rounds):
        w = np.asarray(res.weights[t])
        assert (w[~sched[t]] == 0.0).all(), (scenario, engine, t)
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-5)

    # recorded schedule: executed rows of the resolved matrix, both engines
    assert res.membership == sched[:res.rounds].tolist()
    assert res.membership == res_py.membership

    assert set(res.history) == set(res_py.history)
    for col in res_py.history:
        if col.startswith("comm_") or col == "model_memories":
            assert res.history[col] == res_py.history[col], col
            assert all(isinstance(v, int) for v in res.history[col]), col
        else:
            np.testing.assert_allclose(res.history[col],
                                       res_py.history[col],
                                       rtol=1e-3, atol=1e-3, err_msg=col)
    # the comm ledger shrinks with the live count, per round, exactly
    n = 160 - 160 // 5  # train rows after the 1/5 test split
    exp_b, exp_g = membership_comm_ledger(sched, n, 1, eval_ns=(160 // 5,))
    assert res.history["comm_broadcast_bytes"] == exp_b[:res.rounds]
    assert res.history["comm_gather_bytes"] == exp_g[:res.rounds]

    xs, _, xs_te, _ = _data()
    for t in range(res_py.rounds + 1):
        np.testing.assert_allclose(
            np.asarray(res.predict(xs_te, rounds=t)),
            np.asarray(res_py.predict(xs_te, rounds=t)),
            rtol=1e-3, atol=1e-3,
            err_msg=f"{scenario}/{engine} predict(rounds={t})")


# ---------------------------------------------------------- bitwise parity

@pytest.mark.parametrize("engine", ("scan", "grouped"))
def test_masked_equals_reduced_bitwise(key, engine):
    """THE counterfactual pin: masking org 3 out of every round is bitwise
    identical to fitting only orgs 0..2 — etas, weights over the live
    orgs, the whole train-loss curve, and predict. (No shard cell: a
    3-org reduced mesh cannot exist alongside the 4-org one in-process.)"""
    xs, y, xs_te, _ = _data()
    cfg = GALConfig(rounds=ROUNDS, engine=engine)
    sched = np.ones((ROUNDS, M), bool)
    sched[:, 3] = False
    r4 = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"), cfg,
                 membership=sched)
    r3 = gal.fit(key, make_orgs(xs[:3], Linear()), y, get_loss("mse"), cfg)
    np.testing.assert_array_equal(np.asarray(r4.etas), np.asarray(r3.etas))
    for t in range(ROUNDS):
        w4, w3 = np.asarray(r4.weights[t]), np.asarray(r3.weights[t])
        np.testing.assert_array_equal(w4[:3], w3)
        assert w4[3] == 0.0
    np.testing.assert_array_equal(np.asarray(r4.history["train_loss"]),
                                  np.asarray(r3.history["train_loss"]))
    np.testing.assert_array_equal(np.asarray(r4.predict(xs_te)),
                                  np.asarray(r3.predict(xs_te[:3])))
    # and the ledger equals the reduced org set's static ledger
    n = y.shape[0]
    b3, g3 = gal_round_bytes(n, 1, 3)
    assert r4.history["comm_broadcast_bytes"] == [b3] * ROUNDS
    assert r4.history["comm_gather_bytes"] == [g3] * ROUNDS
    assert (r4.history["model_memories"]
            == gal_model_memories(ROUNDS, [False] * 3))


@pytest.mark.parametrize("engine", ("scan", "grouped"))
def test_join_equals_fresh_fit_with_membership(key, engine):
    """Mid-fit join: resume a 3-org collaboration onto a 4-org set and get
    bitwise the fresh 4-org fit whose schedule masks the joiner before the
    join round — zeroed weight history for the joiner included."""
    xs, y, xs_te, _ = _data()
    t_cut, total = 2, 4
    part = gal.fit(key, make_orgs(xs[:3], Linear()), y, get_loss("mse"),
                   GALConfig(rounds=t_cut, engine=engine))
    grown = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                    GALConfig(rounds=total, engine=engine),
                    resume_from=part)
    sched = np.ones((total, M), bool)
    sched[:t_cut, 3] = False
    fresh = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                    GALConfig(rounds=total, engine=engine),
                    membership=sched)
    np.testing.assert_array_equal(np.asarray(grown.etas),
                                  np.asarray(fresh.etas))
    np.testing.assert_array_equal(np.stack(grown.weights),
                                  np.stack(fresh.weights))
    for t in range(t_cut):                 # joiner's backfilled history
        assert np.asarray(grown.weights[t])[3] == 0.0
    assert grown.membership == sched.tolist() == fresh.membership
    for col in grown.history:
        np.testing.assert_allclose(grown.history[col], fresh.history[col],
                                   rtol=0, atol=0, err_msg=col)
    np.testing.assert_array_equal(np.asarray(grown.predict(xs_te)),
                                  np.asarray(fresh.predict(xs_te)))


def test_loo_resume_matches_scratch_bitwise(key):
    """The contributivity shortcut: a leave-one-out counterfactual resumed
    from the shared round-t0 carry is draw-for-draw identical to running
    the same masked fit from scratch."""
    xs, y, _, _ = _data()
    t0, total = 2, 4
    base = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                   GALConfig(rounds=t0, engine="scan"))
    sched = np.ones((total, M), bool)
    sched[t0:, 1] = False                  # org 1 leaves at the cut
    resumed = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                      GALConfig(rounds=total, engine="scan"),
                      membership=sched, resume_from=base)
    scratch = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                      GALConfig(rounds=total, engine="scan"),
                      membership=sched)
    np.testing.assert_array_equal(np.asarray(resumed.etas),
                                  np.asarray(scratch.etas))
    np.testing.assert_array_equal(np.stack(resumed.weights),
                                  np.stack(scratch.weights))
    np.testing.assert_array_equal(
        np.asarray(resumed.history["train_loss"]),
        np.asarray(scratch.history["train_loss"]))
    assert resumed.membership == scratch.membership


# ------------------------------------------------------- schedules & knobs

def test_straggler_schedule_deterministic_and_never_empty():
    a = straggler_schedule(50, 3, 0.9, seed=11)
    b = straggler_schedule(50, 3, 0.9, seed=11)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (50, 3) and a.dtype == np.bool_
    assert a.any(axis=1).all()             # repair: no empty rounds
    assert not straggler_schedule(50, 3, 0.9, seed=12).tolist() == a.tolist()
    with pytest.raises(ValueError, match="straggler_sim"):
        straggler_schedule(5, 3, 1.0)
    with pytest.raises(ValueError, match="straggler_sim"):
        straggler_schedule(5, 3, -0.1)


def test_resolve_membership_validates_and_composes():
    with pytest.raises(ValueError, match=r"shape \(rounds, M\)"):
        resolve_membership(np.ones((2, 3), bool), None, 0, 3, 3)
    with pytest.raises(ValueError, match="boolean / 0-1"):
        resolve_membership(np.full((2, 2), 0.5), None, 0, 2, 2)
    with pytest.raises(ValueError, match=r"round\(s\) \[1\]"):
        resolve_membership(np.array([[1, 1], [0, 0]]), None, 0, 2, 2)
    assert resolve_membership(None, None, 0, 3, 2) is None
    assert resolve_membership(None, 0.0, 0, 3, 2) is None
    # explicit schedule AND straggler draws compose
    sched = np.ones((6, 2), bool)
    sched[:, 1] = False
    strag = straggler_schedule(6, 2, 0.5, seed=0)
    if (sched & strag).any(axis=1).all():
        out = resolve_membership(sched, 0.5, 0, 6, 2)
        np.testing.assert_array_equal(out, sched & strag)


def test_model_memories_membership_accrual():
    """A fresh org accrues a copy per ATTENDED round; a DMS org holds one
    extractor from its first attended round; a no-show holds nothing."""
    sched = [[True, False, False], [True, True, False], [False, True, False]]
    out = gal_model_memories(3, [False, True, False], membership=sched)
    assert out == [1, 3, 3]
    # all-live membership reproduces the static counts
    ones = [[True] * 3] * 3
    assert (gal_model_memories(3, [False, True, False], membership=ones)
            == gal_model_memories(3, [False, True, False]))


def test_fit_rejects_bad_schedules(key):
    xs, y, _, _ = _data()
    cfg = GALConfig(rounds=ROUNDS, engine="scan")
    with pytest.raises(ValueError, match=r"shape \(rounds, M\)"):
        gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"), cfg,
                membership=np.ones((ROUNDS + 1, M), bool))
    empty = np.ones((ROUNDS, M), bool)
    empty[1] = False
    with pytest.raises(ValueError, match="no live org"):
        gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"), cfg,
                membership=empty)


# ----------------------------------------------------- artifacts & growth

def test_artifact_roundtrips_membership(key, tmp_path):
    from repro.checkpoint import load_artifact, save_artifact
    xs, y, _, _ = _data()
    res = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                  GALConfig(rounds=ROUNDS, engine="scan"),
                  membership=SCHED)
    art = load_artifact(save_artifact(res, tmp_path / "art"))
    assert art.membership == res.membership == SCHED.tolist()
    # membership-free artifacts stay membership-free
    res0 = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                   GALConfig(rounds=ROUNDS, engine="scan"))
    art0 = load_artifact(save_artifact(res0, tmp_path / "art0"))
    assert art0.membership is None


def test_growth_resume_rejections(key):
    xs, y, _, _ = _data()
    part = gal.fit(key, make_orgs(xs[:3], Linear()), y, get_loss("mse"),
                   GALConfig(rounds=2, engine="scan"))
    # straggler fault injection across a growth would retroactively change
    # the (rounds, M) draw matrix
    with pytest.raises(ValueError, match="straggler_sim"):
        gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                GALConfig(rounds=4, engine="scan", straggler_sim=0.3),
                resume_from=part)
    # a shrunk org set is neither a match nor a growth
    with pytest.raises(ValueError, match="not a growth"):
        gal.fit(key, make_orgs(xs[:2], Linear()), y, get_loss("mse"),
                GALConfig(rounds=4, engine="scan"), resume_from=part)
    # DMS groups cannot grow: the extractor/head carry is member-shaped
    dms_part = gal.fit(key, make_orgs(xs[:3], MLP((8,), epochs=5),
                                      dms=True),
                       y, get_loss("mse"),
                       GALConfig(rounds=2, engine="grouped"))
    with pytest.raises(ValueError, match="Deep Model Sharing"):
        gal.fit(key, make_orgs(xs, MLP((8,), epochs=5), dms=True), y,
                get_loss("mse"), GALConfig(rounds=4, engine="grouped"),
                resume_from=dms_part)


def test_grown_resume_roundtrips_as_artifact(key, tmp_path):
    """grow -> save -> load -> predict: the stitched result (zero-padded
    weights, joiner group params, membership ledger) is a first-class
    artifact."""
    from repro.checkpoint import load_artifact, save_artifact
    xs, y, xs_te, _ = _data()
    part = gal.fit(key, make_orgs(xs[:3], Linear()), y, get_loss("mse"),
                   GALConfig(rounds=2, engine="scan"))
    grown = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                    GALConfig(rounds=4, engine="scan"), resume_from=part)
    art = load_artifact(save_artifact(grown, tmp_path / "grown"))
    assert art.membership == grown.membership
    np.testing.assert_array_equal(np.asarray(art.predict(xs_te)),
                                  np.asarray(grown.predict(xs_te)))
