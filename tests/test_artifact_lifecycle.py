"""The GAL artifact lifecycle: fit once, serve forever, resume anywhere.

Three contracts pinned here, per engine x scenario:

  * **save -> load -> predict parity**: ``load_artifact(save_artifact(r))``
    predicts bitwise-identically to the in-memory result at EVERY round
    prefix on single-host placements (scan / grouped); mesh-sharded
    results (shard, grouped-over-mesh) are compared to float tolerance —
    the in-memory result intentionally keeps its params sharded, so its
    predict runs GSPMD-partitioned reductions the replicated loaded copy
    does not.
  * **resume conformance**: a fit interrupted at round t0 and resumed to T
    reproduces the uninterrupted T-round fit draw for draw — etas,
    assistance weights, and every history column bitwise, both when
    resuming from the in-memory result and from the on-disk artifact.
  * **manifest compat**: every mismatch an artifact can hit at load or
    resume time (schema version, plan shape, model config, config fields,
    losses, eval sets, round cursor) raises with the specific reason.
"""
import json

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import (ARTIFACT_SCHEMA, load_artifact, save_artifact)
from repro.core import gal
from repro.core.gal import GALConfig
from repro.core.losses import get_loss
from repro.core.organizations import make_orgs
from repro.data.partition import split_features
from repro.data.synthetic import make_regression, train_test_split
from repro.launch.mesh import org_mesh_eligible
from repro.models.zoo import KernelRidge, Linear, MLP, StumpBoost

M = 4
ROUNDS = 4
T_CUT = 2


def _pseudo_huber(r, f):
    return jnp.mean(jnp.sqrt(1.0 + jnp.square(r - f)) - 1.0)


def _data():
    rng_np = np.random.default_rng(3)
    ds = make_regression(rng_np, n=120, d=12)
    tr, te = train_test_split(ds, rng_np)
    return (split_features(tr.x, M), tr.y,
            split_features(te.x, M), te.y)


SCENARIOS = {
    "homogeneous": dict(
        orgs=lambda xs: make_orgs(xs, Linear()),
        engines=("scan", "shard")),
    "hetero": dict(
        orgs=lambda xs: make_orgs(
            xs, [StumpBoost(n_stumps=8) if i % 2 == 0 else KernelRidge()
                 for i in range(M)]),
        engines=("grouped",)),
    "noisy": dict(
        orgs=lambda xs: make_orgs(xs, Linear(),
                                  noise_sigmas=[0.0, 1.0, 0.0, 1.0]),
        engines=("grouped",)),
    "dms": dict(
        orgs=lambda xs: make_orgs(xs, MLP((8,), epochs=5), dms=True),
        engines=("grouped",)),
}

_CELLS = [(s, e) for s, spec in SCENARIOS.items() for e in spec["engines"]]


def _skip_without_mesh(engine):
    if engine == "shard" and not org_mesh_eligible(M):
        pytest.skip(f"no org mesh for {M} orgs (run under "
                    f"REPRO_FORCE_DEVICES={M})")


def _fit(scenario, engine, key, rounds=ROUNDS, **extra):
    xs, y, xs_te, y_te = _data()
    orgs = SCENARIOS[scenario]["orgs"](xs)
    return gal.fit(key, orgs, y, get_loss("mse"),
                   GALConfig(rounds=rounds, engine=engine),
                   eval_sets={"test": (xs_te, y_te)}, metrics=("mad",),
                   **extra)


def _assert_predict_parity(res_a, res_b, xs_te, mesh_placed):
    for t in range(res_a.rounds + 1):
        a = np.asarray(res_a.predict(xs_te, rounds=t))
        b = np.asarray(res_b.predict(xs_te, rounds=t))
        if mesh_placed:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6,
                                       err_msg=f"rounds={t}")
        else:
            np.testing.assert_array_equal(a, b, err_msg=f"rounds={t}")


# --------------------------------------------------------------- save/load

@pytest.mark.parametrize("scenario,engine", _CELLS,
                         ids=[f"{s}-{e}" for s, e in _CELLS])
def test_save_load_predict_parity(tmp_path, key, scenario, engine):
    _skip_without_mesh(engine)
    res = _fit(scenario, engine, key)
    art = load_artifact(save_artifact(res, tmp_path / "art"))
    xs, _, xs_te, _ = _data()
    assert art.engine == res.engine
    assert art.rounds == res.rounds
    assert art.plan.describe() == res.plan.describe()
    assert art.group_pads == res.group_pads
    np.testing.assert_array_equal(np.asarray(art.f0), np.asarray(res.f0))
    np.testing.assert_array_equal(res.etas, art.etas)
    assert set(art.history) == set(res.history)
    for col in res.history:
        np.testing.assert_allclose(art.history[col], res.history[col],
                                   rtol=0, atol=0, err_msg=col)
        if col.startswith("comm_") or col == "model_memories":
            assert all(isinstance(v, int) for v in art.history[col]), col
    mesh_placed = res.engine == "shard" or res.mesh_devices > 0
    _assert_predict_parity(res, art, xs_te, mesh_placed)
    # the training slices replay too (the Fig. 4 protocol reads them)
    _assert_predict_parity(res, art, xs, mesh_placed)


def test_manifest_is_versioned_and_self_describing(tmp_path, key):
    res = _fit("homogeneous", "scan", key)
    path = save_artifact(res, tmp_path / "art")
    man = json.loads((path / "manifest.json").read_text())
    assert man["schema"] == ARTIFACT_SCHEMA
    assert man["t_next"] == ROUNDS and man["rounds"] == ROUNDS
    assert man["n_orgs"] == M and man["eval_names"] == ["test"]
    assert len(man["plan"]["groups"]) == res.plan.n_groups
    g0 = man["plan"]["groups"][0]
    assert g0["model"]["kind"] == "zoo" and g0["model"]["name"] == "linear"
    assert g0["local_loss"] == {"kind": "lq", "q": 2.0}
    assert man["config"]["rounds"] == ROUNDS


def test_loaded_artifact_has_no_live_orgs(tmp_path, key):
    res = _fit("homogeneous", "scan", key)
    art = load_artifact(save_artifact(res, tmp_path / "art"))
    assert art.orgs == []
    with pytest.raises(ValueError, match="no Organizations attached"):
        art.unpack_to_orgs()
    with pytest.raises(ValueError, match="no Organizations attached"):
        art.predict_legacy([jnp.zeros((2, 3))] * M)


def test_python_result_cannot_be_saved(tmp_path, key):
    xs, y, _, _ = _data()

    class NotScanSafe:
        def fit(self, rng, x, r, loss):
            return {"w": jnp.zeros((x.shape[-1], r.shape[-1]))}

        def apply(self, params, x):
            return x @ params["w"]

        def init(self, rng, x, k):
            return {"w": jnp.zeros((x.shape[-1], k))}

    res = gal.fit(key, make_orgs(xs, NotScanSafe()), y, get_loss("mse"),
                  GALConfig(rounds=1))
    assert res.engine == "python"
    with pytest.raises(ValueError, match="compiled-engine"):
        save_artifact(res, tmp_path / "art")


def test_custom_loss_artifact_requires_resolver(tmp_path, key):
    xs, y, xs_te, _ = _data()
    orgs = lambda: make_orgs(xs, Linear(epochs=10),             # noqa: E731
                             local_losses=_pseudo_huber)
    res = gal.fit(key, orgs(), y, get_loss("mse"),
                  GALConfig(rounds=2, engine="grouped"))
    path = save_artifact(res, tmp_path / "art")
    with pytest.raises(ValueError, match="_pseudo_huber"):
        load_artifact(path)
    art = load_artifact(path, losses={"_pseudo_huber": _pseudo_huber})
    np.testing.assert_array_equal(np.asarray(res.predict(xs_te)),
                                  np.asarray(art.predict(xs_te)))


def test_custom_loss_resume_by_path(tmp_path, key):
    """Resuming FROM A PATH with custom (name-only) losses must work
    without explicit resolver maps: gal.fit resolves the artifact's names
    against the org set being resumed."""
    xs, y, _, _ = _data()
    orgs = lambda: make_orgs(xs, Linear(epochs=10),             # noqa: E731
                             local_losses=_pseudo_huber)
    cfg = dict(engine="grouped")
    one_shot = gal.fit(key, orgs(), y, get_loss("mse"),
                       GALConfig(rounds=ROUNDS, **cfg))
    part = gal.fit(key, orgs(), y, get_loss("mse"),
                   GALConfig(rounds=T_CUT, **cfg))
    path = save_artifact(part, tmp_path / "part")
    resumed = gal.fit(key, orgs(), y, get_loss("mse"),
                      GALConfig(rounds=ROUNDS, **cfg),
                      resume_from=str(path))
    np.testing.assert_array_equal(one_shot.etas, resumed.etas)


class _TupleParamRidge:
    """A custom scan-safe model whose params pytree contains a TUPLE —
    the self-describing npz form stores it as a list, so the resume
    stitcher must concatenate by leaf order, not by two-tree treedef."""
    scan_safe = True
    pad_invariant = True

    def init(self, rng, x_example, k_out):
        return {"wb": (jnp.zeros((x_example.shape[-1], k_out)),
                       jnp.zeros((k_out,)))}

    def fit(self, rng, x, r, local_loss):
        n, d = x.shape
        xb = jnp.concatenate([x, jnp.ones((n, 1))], axis=1)
        sol = jnp.linalg.solve(xb.T @ xb + 1e-3 * jnp.eye(d + 1), xb.T @ r)
        return {"wb": (sol[:-1], sol[-1])}

    def apply(self, params, x):
        w, b = params["wb"]
        return x @ w + b


def test_tuple_param_custom_model_resumes_from_disk(tmp_path, key):
    xs, y, _, _ = _data()
    model = _TupleParamRidge()
    mk = lambda: make_orgs(xs, model)                           # noqa: E731
    cfg = dict(engine="grouped")
    one_shot = gal.fit(key, mk(), y, get_loss("mse"),
                       GALConfig(rounds=ROUNDS, **cfg))
    part = gal.fit(key, mk(), y, get_loss("mse"),
                   GALConfig(rounds=T_CUT, **cfg))
    path = save_artifact(part, tmp_path / "part")
    resumed = gal.fit(key, mk(), y, get_loss("mse"),
                      GALConfig(rounds=ROUNDS, **cfg),
                      resume_from=str(path))
    np.testing.assert_array_equal(one_shot.etas, resumed.etas)


def test_load_rejects_wrong_schema_and_non_artifact(tmp_path, key):
    res = _fit("homogeneous", "scan", key)
    path = save_artifact(res, tmp_path / "art")
    man = json.loads((path / "manifest.json").read_text())
    man["schema"] = "gal-artifact/v999"
    (path / "manifest.json").write_text(json.dumps(man))
    with pytest.raises(ValueError, match="unsupported artifact schema"):
        load_artifact(path)
    with pytest.raises(ValueError, match="not a GAL artifact"):
        load_artifact(tmp_path / "nowhere")


# ------------------------------------------------------------------ resume

@pytest.mark.parametrize("scenario,engine", _CELLS,
                         ids=[f"{s}-{e}" for s, e in _CELLS])
def test_resume_matches_one_shot(tmp_path, key, scenario, engine):
    """Fit T_CUT rounds, save, resume to ROUNDS (from disk AND in memory):
    etas, weights, and EVERY history column must equal the uninterrupted
    ROUNDS-round fit bitwise — the resumed carry restores the exact
    round-scan state, and the RNG chain continues where it left off."""
    _skip_without_mesh(engine)
    one_shot = _fit(scenario, engine, key)
    part = _fit(scenario, engine, key, rounds=T_CUT)
    path = save_artifact(part, tmp_path / "part")

    for label, src in (("disk", str(path)), ("memory", part)):
        resumed = _fit(scenario, engine, key, resume_from=src)
        assert resumed.rounds == one_shot.rounds, label
        np.testing.assert_array_equal(one_shot.etas, resumed.etas,
                                      err_msg=label)
        np.testing.assert_array_equal(np.stack(one_shot.weights),
                                      np.stack(resumed.weights),
                                      err_msg=label)
        assert set(resumed.history) == set(one_shot.history), label
        for col in one_shot.history:
            np.testing.assert_allclose(resumed.history[col],
                                       one_shot.history[col],
                                       rtol=0, atol=0,
                                       err_msg=f"{label}/{col}")
        xs, _, xs_te, _ = _data()
        mesh_placed = one_shot.engine == "shard" or one_shot.mesh_devices > 0
        _assert_predict_parity(one_shot, resumed, xs_te, mesh_placed)
        # the resumed result is itself resumable and saveable
        assert resumed.resume_state is not None
        assert int(resumed.resume_state["t_next"]) == ROUNDS


def test_resumed_artifact_round_trips(tmp_path, key):
    """resume -> save -> load -> predict: the stitched result is a
    first-class artifact (params concatenated across the cut)."""
    one_shot = _fit("homogeneous", "scan", key)
    part = _fit("homogeneous", "scan", key, rounds=T_CUT)
    resumed = _fit("homogeneous", "scan", key, resume_from=part)
    art = load_artifact(save_artifact(resumed, tmp_path / "art"))
    _, _, xs_te, _ = _data()
    _assert_predict_parity(one_shot, art, xs_te, mesh_placed=False)


def test_early_stopped_artifact_resumes_to_noop(key, tmp_path):
    """An artifact whose fit already crossed eta_stop_threshold appends
    nothing on resume — exactly like the longer one-shot fit."""
    xs, y, xs_te, y_te = _data()
    cfg = dict(eta_stop_threshold=10.0, engine="scan")
    one_shot = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                       GALConfig(rounds=6, **cfg),
                       eval_sets={"test": (xs_te, y_te)})
    part = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                   GALConfig(rounds=3, **cfg),
                   eval_sets={"test": (xs_te, y_te)})
    assert part.rounds < 3        # the threshold bites immediately
    resumed = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                      GALConfig(rounds=6, **cfg),
                      eval_sets={"test": (xs_te, y_te)}, resume_from=part)
    np.testing.assert_array_equal(one_shot.etas, resumed.etas)
    for col in one_shot.history:
        np.testing.assert_allclose(resumed.history[col],
                                   one_shot.history[col], rtol=0, atol=0,
                                   err_msg=col)


# ------------------------------------------------------- mismatch guards

def test_resume_rejects_plan_mismatch(key):
    part = _fit("homogeneous", "scan", key, rounds=T_CUT)
    xs, y, xs_te, y_te = _data()
    wrong = make_orgs(xs, [StumpBoost(n_stumps=8) if i % 2 == 0
                           else KernelRidge() for i in range(M)])
    with pytest.raises(ValueError, match="does not match the artifact"):
        gal.fit(key, wrong, y, get_loss("mse"),
                GALConfig(rounds=ROUNDS, engine="grouped"),
                eval_sets={"test": (xs_te, y_te)}, metrics=("mad",),
                resume_from=part)


def test_resume_rejects_model_config_drift(key):
    part = _fit("homogeneous", "scan", key, rounds=T_CUT)
    xs, y, xs_te, y_te = _data()
    drifted = make_orgs(xs, Linear(ridge=0.5))
    with pytest.raises(ValueError, match="model mismatch"):
        gal.fit(key, drifted, y, get_loss("mse"),
                GALConfig(rounds=ROUNDS, engine="scan"),
                eval_sets={"test": (xs_te, y_te)}, metrics=("mad",),
                resume_from=part)


def test_resume_rejects_config_and_loss_drift(key):
    part = _fit("homogeneous", "scan", key, rounds=T_CUT)
    xs, y, xs_te, y_te = _data()
    with pytest.raises(ValueError, match="config mismatch.*eta_method"):
        gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                GALConfig(rounds=ROUNDS, engine="scan",
                          eta_method="golden"),
                eval_sets={"test": (xs_te, y_te)}, metrics=("mad",),
                resume_from=part)
    with pytest.raises(ValueError, match="loss mismatch"):
        gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mae"),
                GALConfig(rounds=ROUNDS, engine="scan"),
                eval_sets={"test": (xs_te, y_te)}, metrics=("mad",),
                resume_from=part)


def test_resume_rejects_rounds_not_beyond_cursor(key):
    part = _fit("homogeneous", "scan", key, rounds=T_CUT)
    xs, y, xs_te, y_te = _data()
    with pytest.raises(ValueError, match="rounds >"):
        gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                GALConfig(rounds=T_CUT, engine="scan"),
                eval_sets={"test": (xs_te, y_te)}, metrics=("mad",),
                resume_from=part)


def test_resume_rejects_different_training_targets(key):
    """Same-shape-but-different y must be caught (F^0 is a deterministic
    function of y): a restored carry on drifted data would silently
    produce rounds no uninterrupted fit could."""
    part = _fit("homogeneous", "scan", key, rounds=T_CUT)
    xs, y, xs_te, y_te = _data()
    with pytest.raises(ValueError, match="does not look like the data"):
        gal.fit(key, make_orgs(xs, Linear()), y + 1.0, get_loss("mse"),
                GALConfig(rounds=ROUNDS, engine="scan"),
                eval_sets={"test": (xs_te, y_te)}, metrics=("mad",),
                resume_from=part)


def test_resume_rejects_eval_set_mismatch(key):
    part = _fit("homogeneous", "scan", key, rounds=T_CUT)
    xs, y, xs_te, y_te = _data()
    with pytest.raises(ValueError, match="eval"):
        gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                GALConfig(rounds=ROUNDS, engine="scan"),
                eval_sets={"holdout": (xs_te, y_te)}, metrics=("mad",),
                resume_from=part)


def test_resume_rejects_python_engine_and_python_results(key):
    part = _fit("homogeneous", "scan", key, rounds=T_CUT)
    xs, y, xs_te, y_te = _data()
    with pytest.raises(ValueError, match="compiled engine"):
        gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                GALConfig(rounds=ROUNDS, engine="python"),
                eval_sets={"test": (xs_te, y_te)}, metrics=("mad",),
                resume_from=part)
    res_py = gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                     GALConfig(rounds=T_CUT, engine="python"),
                     eval_sets={"test": (xs_te, y_te)}, metrics=("mad",))
    with pytest.raises(ValueError, match="no resume state"):
        gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                GALConfig(rounds=ROUNDS, engine="scan"),
                eval_sets={"test": (xs_te, y_te)}, metrics=("mad",),
                resume_from=res_py)


def test_resume_rejects_metric_column_drift(key):
    part = _fit("homogeneous", "scan", key, rounds=T_CUT)
    xs, y, xs_te, y_te = _data()
    with pytest.raises(ValueError, match="history columns"):
        gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"),
                GALConfig(rounds=ROUNDS, engine="scan"),
                eval_sets={"test": (xs_te, y_te)}, metrics=("mad", "auroc"),
                resume_from=part)
