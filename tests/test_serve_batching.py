"""The inference service: bucketed batching parity + registry lifecycle.

The load-bearing guarantee is **packing safety**: the batcher may pad a
request to a bucket shape and pack it with strangers (same tenant), and
at that fixed bucket shape the rows that come back are BITWISE
independent of the batch content around them — zero pad, garbage pad,
or co-packed requests all land in other rows of the row-independent
Prediction Stage. Across *different* bucket shapes XLA may round the
same row differently (it specializes on the batch dimension), which is
exactly why the bucket choice is a deterministic function of the
request size: the same request always runs the same compiled program
and returns the same bits.

Also pinned here: the registry's lazy load / LRU evict / reload cycle
serves bitwise-identical outputs across reloads, the deadline flush
policy under a fake clock, and that concurrent submissions across
tenants never leak rows into another tenant's launch.
"""
import threading
import time
from concurrent.futures import Future

import jax
import numpy as np
import pytest

from repro.core import gal
from repro.core.gal import GALConfig
from repro.core.losses import get_loss
from repro.core.organizations import make_orgs
from repro.data.partition import split_features
from repro.data.synthetic import make_regression, train_test_split
from repro.models.zoo import Linear
from repro.serve import (ArtifactRegistry, BucketedPredict, GALService,
                         MicroBatcher, bucket_for, bucket_sizes, pad_rows,
                         request_widths, run_load, run_serial)

ORGS, D_TOTAL, ROUNDS = 3, 12, 3


def _fit(seed=0, noise_sigmas=None):
    rng = np.random.default_rng(seed)
    ds = make_regression(rng, n=128, d=D_TOTAL)
    train, test = train_test_split(ds, rng)
    xs = split_features(train.x, ORGS)
    # noisy orgs route through the grouped engine ('auto' picks it)
    engine = "auto" if noise_sigmas else "scan"
    res = gal.fit(jax.random.PRNGKey(seed),
                  make_orgs(xs, Linear(), noise_sigmas=noise_sigmas),
                  train.y, get_loss("mse"),
                  GALConfig(rounds=ROUNDS, engine=engine))
    xs_te = [np.asarray(x) for x in split_features(test.x, ORGS)]
    return res, xs_te


@pytest.fixture(scope="module")
def fitted():
    return _fit(0)


@pytest.fixture(scope="module")
def fitted_other():
    return _fit(1)


# --------------------------------------------------------------------------
# bucket policy units
# --------------------------------------------------------------------------

def test_bucket_sizes_powers_of_two_plus_max():
    assert bucket_sizes(64) == (1, 2, 4, 8, 16, 32, 64)
    assert bucket_sizes(48) == (1, 2, 4, 8, 16, 32, 48)
    assert bucket_sizes(1) == (1,)
    with pytest.raises(ValueError):
        bucket_sizes(0)


def test_bucket_for_smallest_holding_bucket():
    buckets = bucket_sizes(16)
    assert [bucket_for(n, buckets) for n in (1, 2, 3, 5, 8, 9, 16)] == \
        [1, 2, 4, 8, 8, 16, 16]
    with pytest.raises(ValueError):
        bucket_for(0, buckets)
    with pytest.raises(ValueError, match="exceed"):
        bucket_for(17, buckets)


def test_pad_rows_zero_pads_fresh_buffers():
    xs = [np.arange(6, dtype=np.float32).reshape(2, 3)]
    (padded,) = pad_rows(xs, 4)
    assert padded.shape == (4, 3)
    np.testing.assert_array_equal(padded[:2], xs[0])
    np.testing.assert_array_equal(padded[2:], 0.0)
    # exact-fit requests are passed through, larger targets are fresh
    assert pad_rows(xs, 2)[0] is not padded


# --------------------------------------------------------------------------
# bitwise parity: bucketed/padded serving never changes an answer
# --------------------------------------------------------------------------

def test_bucketed_bitwise_vs_unbatched_at_every_bucket_size(fitted):
    """A request of exactly bucket-size rows goes through the SAME batch
    shape the unbatched jitted predict would compile — bitwise equal."""
    res, xs_te = fitted
    bp = BucketedPredict(lambda xq: res.predict(xq), max_batch=16)
    unbatched = jax.jit(lambda xq: res.predict(xq))
    for b in bp.buckets:
        req = [x[:b] for x in xs_te]
        np.testing.assert_array_equal(np.asarray(bp(req)),
                                      np.asarray(unbatched(req)))


def test_ragged_rows_bitwise_independent_of_batch_content(fitted):
    """Ragged requests are padded up to their bucket. At that FIXED
    bucket shape a row's bits must not depend on what else is in the
    batch — zero pad, garbage pad, or co-packed strangers all land in
    other rows of a row-independent prediction. (Across DIFFERENT bucket
    shapes XLA may round differently — which is exactly why the bucket
    choice is a deterministic function of the request size.)"""
    res, xs_te = fitted
    rng = np.random.default_rng(7)
    bp = BucketedPredict(lambda xq: res.predict(xq), max_batch=16)
    unbatched = jax.jit(lambda xq: res.predict(xq))
    for n in (1, 3, 5, 7, 9, 15):
        req = [x[:n] for x in xs_te]
        b = bucket_for(n, bp.buckets)
        got = np.asarray(bp(req))
        assert got.shape[0] == n
        # deterministic: the same request always takes the same bucket
        np.testing.assert_array_equal(got, np.asarray(bp(req)))
        # zero pad vs garbage pad at the same bucket shape: same bits
        for pad in (np.zeros, lambda s, d: rng.normal(size=s).astype(d)):
            full = [np.concatenate(
                [x[:n], np.asarray(pad((b - n,) + x.shape[1:],
                                       x.dtype))]) if b > n else x[:n]
                for x in xs_te]
            np.testing.assert_array_equal(
                got, np.asarray(unbatched(full))[:n],
                err_msg=f"pad content changed bits at bucket {b}, n={n}")


def test_packed_requests_bitwise_equal_to_packed_launch(fitted):
    """The micro-batcher guarantee: each packed request gets back exactly
    its own rows of the bucket-shaped launch that actually ran (bitwise
    vs a hand-packed reference at the same shape), and those rows agree
    with serving the request alone to float precision (a different
    bucket shape may round differently — see the ragged test)."""
    res, xs_te = fitted
    bp = BucketedPredict(lambda xq: res.predict(xq), max_batch=16)
    unbatched = jax.jit(lambda xq: res.predict(xq))
    reqs = [[x[i:i + 1] for x in xs_te] for i in range(5)]

    mb = MicroBatcher(lambda: bp, auto_flush=False)
    futs = [mb.submit(r) for r in reqs]
    assert mb.flush() == 5
    # hand-pack the same 5 rows to the same bucket (5 -> 8) and launch
    packed = pad_rows([np.concatenate([np.asarray(r[m]) for r in reqs])
                       for m in range(len(xs_te))], 8)
    ref = np.asarray(unbatched(packed))[:5]
    for i, fut in enumerate(futs):
        got = np.asarray(fut.result(timeout=0))
        np.testing.assert_array_equal(got, ref[i:i + 1])
        np.testing.assert_allclose(got, np.asarray(bp(reqs[i])),
                                   rtol=1e-5, atol=1e-6)


def test_microbatcher_chunks_oversized_flushes(fitted):
    """Pending rows past max_batch are chunked into several launches —
    results still route back to the right request, bitwise equal to
    hand-launching the same chunks at the same shapes."""
    res, xs_te = fitted
    bp = BucketedPredict(lambda xq: res.predict(xq), max_batch=4)
    unbatched = jax.jit(lambda xq: res.predict(xq))
    mb = MicroBatcher(lambda: bp, auto_flush=False)
    reqs = [[x[i * 2:i * 2 + 2] for x in xs_te] for i in range(3)]  # 6 rows
    futs = [mb.submit(r) for r in reqs]
    assert mb.flush() == 3
    # the flush chunks pending rows [0:4] (bucket 4) and [4:6] (bucket 2)
    cat = [np.concatenate([np.asarray(r[m]) for r in reqs])
           for m in range(len(xs_te))]
    ref = np.concatenate([np.asarray(unbatched([c[:4] for c in cat])),
                          np.asarray(unbatched([c[4:6] for c in cat]))])
    for i, fut in enumerate(futs):
        np.testing.assert_array_equal(np.asarray(fut.result(timeout=0)),
                                      ref[i * 2:i * 2 + 2])
    assert mb.stats()["rows"] == 6
    assert bp.launches >= 2          # 6 rows cannot fit one 4-row launch


def test_jit_cache_bounded_by_bucket_count(fitted):
    res, xs_te = fitted
    bp = BucketedPredict(lambda xq: res.predict(xq), max_batch=8)
    widths = [x.shape[1] for x in xs_te]
    assert bp.compile_buckets(widths) == len(bp.buckets) == 4
    for n in range(1, 9):            # every size maps onto a warm bucket
        bp([x[:n] for x in xs_te])
    assert bp.rows_padded > 0


# --------------------------------------------------------------------------
# registry: lazy load, LRU eviction, reload parity, rejection
# --------------------------------------------------------------------------

def test_registry_lazy_load_evict_reload_bitwise(fitted, tmp_path):
    from repro.checkpoint import save_artifact
    res, xs_te = fitted
    save_artifact(res, tmp_path / "art")

    reg = ArtifactRegistry(max_batch=8)
    reg.register("acme", tmp_path / "art")
    assert "acme" in reg and not reg.is_loaded("acme")
    assert reg.loads == 0            # registration peeks the manifest only

    req = [x[:3] for x in xs_te]
    first = np.asarray(reg.get("acme").predict(req))
    assert reg.is_loaded("acme") and reg.loads == 1

    assert reg.evict("acme") and not reg.is_loaded("acme")
    assert not reg.evict("acme")     # already out
    again = np.asarray(reg.get("acme").predict(req))
    assert reg.loads == 2 and reg.get("acme").loads == 2
    np.testing.assert_array_equal(first, again)


def test_registry_lru_eviction_bounded(fitted, fitted_other, tmp_path):
    from repro.checkpoint import save_artifact
    res_a, xs_te = fitted
    res_b, _ = fitted_other
    save_artifact(res_a, tmp_path / "a")
    save_artifact(res_b, tmp_path / "b")
    reg = ArtifactRegistry(max_loaded=1, max_batch=8)
    reg.register("a", tmp_path / "a")
    reg.register("b", tmp_path / "b")
    reg.get("a")
    reg.get("b")                     # evicts a (LRU)
    assert reg.is_loaded("b") and not reg.is_loaded("a")
    assert reg.evictions == 1
    reg.get("a")                     # transparently reloads
    assert reg.is_loaded("a") and not reg.is_loaded("b")
    assert reg.stats()["loads"] == 3


def test_registry_rejects_unknown_and_unservable(fitted):
    res, _ = fitted
    reg = ArtifactRegistry()
    with pytest.raises(ValueError, match="unknown tenant"):
        reg.get("nobody")
    with pytest.raises(ValueError, match="not an artifact|manifest"):
        reg.register("bad", "/nonexistent/artifact-dir")

    noisy, _ = _fit(2, noise_sigmas=[0.5] * ORGS)
    with pytest.raises(ValueError, match="noisy"):
        reg.register("noisy", noisy)


def test_request_widths_and_validation(fitted):
    res, xs_te = fitted
    widths = request_widths(res)
    assert widths == [x.shape[1] for x in xs_te]

    reg = ArtifactRegistry(max_batch=8)
    reg.register("t", res)
    entry = reg.get("t")
    req = [x[:2] for x in xs_te]
    entry.validate_request(req)      # well-formed
    with pytest.raises(ValueError, match="organizations"):
        entry.validate_request(req[:-1])
    with pytest.raises(ValueError, match="row count"):
        entry.validate_request([xs_te[0][:2]] + [x[:3] for x in xs_te[1:]])
    with pytest.raises(ValueError, match="column"):
        entry.validate_request([x[:2, :-1] for x in xs_te])


# --------------------------------------------------------------------------
# deadline flush policy under a fake clock (no sleeping)
# --------------------------------------------------------------------------

class FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now


def test_deadline_flush_fires_on_age_or_rows(fitted):
    res, xs_te = fitted
    bp = BucketedPredict(lambda xq: res.predict(xq), max_batch=8)
    clock = FakeClock()
    mb = MicroBatcher(lambda: bp, deadline_s=0.002, flush_rows=4,
                      clock=clock, auto_flush=False)
    req = [x[:1] for x in xs_te]

    fut = mb.submit(req)
    assert mb.poll() == 0            # 1 row < flush_rows, age 0 < deadline
    clock.now = 0.0019
    assert mb.poll() == 0            # still inside the deadline
    clock.now = 0.0021
    assert mb.poll() == 1            # oldest request aged out -> flush
    assert fut.done()

    futs = [mb.submit(req) for _ in range(4)]
    assert mb.poll() == 4            # flush_rows reached: no age needed
    assert all(f.done() for f in futs)
    assert mb.poll() == 0            # nothing pending


def test_flusher_thread_drains_on_close(fitted):
    res, xs_te = fitted
    bp = BucketedPredict(lambda xq: res.predict(xq), max_batch=8)
    mb = MicroBatcher(lambda: bp, deadline_s=0.001)
    fut = mb.submit([x[:1] for x in xs_te])
    got = fut.result(timeout=5.0)    # background flusher resolves it
    assert np.asarray(got).shape[0] == 1
    mb.close()
    with pytest.raises(RuntimeError, match="closed"):
        mb.submit([x[:1] for x in xs_te])


# --------------------------------------------------------------------------
# the service: tenant isolation under concurrent submission
# --------------------------------------------------------------------------

def test_concurrent_submissions_never_mix_tenants(fitted, fitted_other):
    """Two tenants with different fitted params, many threads submitting
    interleaved single-row requests: after a flush-all, every result is
    bitwise the submitting tenant's own prediction — a mixed-up batch
    would return another collaboration's numbers."""
    res_a, xs_a = fitted
    res_b, xs_b = fitted_other
    reg = ArtifactRegistry(max_batch=8)
    reg.register("a", res_a)
    reg.register("b", res_b)
    svc = GALService(reg, auto_flush=False, clock=FakeClock())

    # per-row references at bucket shape 4 — the shape each tenant's
    # 4-row flush launches. Concurrent arrival order decides each row's
    # POSITION in its batch, so assert to float precision: the two
    # collaborations' predictions differ grossly, so any cross-tenant
    # leak fails loudly. (Bitwise routing at a fixed packing order is
    # pinned by test_packed_requests_bitwise_equal_to_packed_launch.)
    want = {"a": {}, "b": {}}
    for tenant, res, xs in (("a", res_a, xs_a), ("b", res_b, xs_b)):
        ref = np.asarray(jax.jit(lambda xq, _r=res: _r.predict(xq))(
            [x[:4] for x in xs]))
        for i in range(4):
            want[tenant][i] = ref[i:i + 1]

    results, lock = [], threading.Lock()

    def client(tenant, xs, i):
        fut = svc.submit(tenant, [x[i:i + 1] for x in xs])
        with lock:
            results.append((tenant, i, fut))

    threads = [threading.Thread(target=client, args=(t, xs, i))
               for i in range(4) for t, xs in (("a", xs_a), ("b", xs_b))]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert svc.flush() == 8          # both tenants' batchers drain
    for tenant, i, fut in results:
        np.testing.assert_allclose(np.asarray(fut.result(timeout=0)),
                                   want[tenant][i], rtol=1e-5, atol=1e-6,
                                   err_msg=f"tenant {tenant} row {i}")
    stats = svc.stats()["tenants"]
    assert stats["a"]["rows"] == 4 and stats["b"]["rows"] == 4
    svc.close()


def test_service_validates_before_enqueue(fitted):
    res, xs_te = fitted
    reg = ArtifactRegistry(max_batch=8)
    reg.register("t", res)
    svc = GALService(reg, auto_flush=False, clock=FakeClock())
    with pytest.raises(ValueError, match="organizations"):
        svc.submit("t", [x[:1] for x in xs_te][:-1])
    with pytest.raises(ValueError, match="unknown tenant"):
        svc.submit("ghost", [x[:1] for x in xs_te])
    svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit("t", [x[:1] for x in xs_te])


def test_load_harness_round_trips_every_request(fitted, fitted_other):
    res_a, xs_a = fitted
    res_b, xs_b = fitted_other
    reg = ArtifactRegistry(max_batch=8)
    reg.register("a", res_a)
    reg.register("b", res_b)
    requests = []
    for i in range(24):
        tenant, xs = (("a", xs_a), ("b", xs_b))[i % 2]
        requests.append((tenant, [x[i % 8:i % 8 + 1] for x in xs]))

    serial = run_serial(reg, requests)
    assert serial["requests"] == 24 and serial["requests_per_sec"] > 0

    svc = GALService(reg, deadline_s=0.001)
    try:
        load = run_load(svc, requests, clients=4, depth=2)
    finally:
        svc.close()
    assert load["requests"] == 24 and load["depth"] == 2
    assert load["p99_ms"] >= load["p50_ms"] > 0


# --------------------------------------------------------------------------
# serve-CLI measurement helper (--steps 0 regression)
# --------------------------------------------------------------------------

def test_measure_request_path_steps_zero_and_semantics():
    from repro.launch.serve import measure_request_path
    assert measure_request_path(lambda: 0, 0) == (None, None)
    calls = []

    def fn():
        calls.append(1)
        return np.zeros(())

    lat, thr = measure_request_path(fn, 3)
    assert len(calls) == 6           # 3 blocked + 3 pipelined
    assert lat > 0 and thr > 0
