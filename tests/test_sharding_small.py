"""Sharding policy on a small in-process device mesh.

These tests run in a subprocess with XLA_FLAGS forcing 8 host devices (jax
locks the device count on first init — the main test process must stay at 1
device so the rest of the suite sees a normal CPU).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_arch
    from repro.launch import sharding as shd
    from repro.launch.mesh import make_device_mesh
    from repro.launch.specs import abstract_params, train_batch_specs
    from repro.configs.base import SHAPES, InputShape
    from repro.models import pspec as act_hints
    from repro.models import transformer as tfm
    from repro.train.steps import make_train_step

    mesh = make_device_mesh((2, 4), ("data", "model"))
    act_hints.set_mesh(mesh)
    cfg = get_arch("llama3-8b", smoke=True)

    # real (not abstract) run: init sharded params, run one train step
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    p_sh = shd.params_shardings(cfg, mesh, params)
    params = jax.device_put(params, p_sh)
    step, opt = make_train_step(cfg, "lm_xent", lr=1e-3)
    opt_state = opt.init(params)
    batch = {
        "tokens": jnp.zeros((8, 32), jnp.int32),
        "labels": jnp.zeros((8, 32), jnp.int32),
    }
    b_sh = shd.batch_shardings(cfg, mesh, {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()})
    batch = {k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}
    with mesh:
        params2, opt_state, metrics = jax.jit(step)(params, opt_state, batch)
    out = {
        "loss": float(metrics["loss"]),
        "n_devices": len(jax.devices()),
        "wq_sharded": str(
            jax.tree_util.tree_leaves(params2)[0].sharding is not None),
    }
    # params stay distributed through the step: every big weight remains
    # sharded (not replicated) even though XLA may re-express the sharding
    flat_out = jax.tree_util.tree_flatten_with_path(params2)[0]
    big = [l for _, l in flat_out if l.size >= 64 * 64]
    out["shardings_preserved"] = all(
        not l.sharding.is_fully_replicated for l in big)
    print("RESULT:" + json.dumps(out))
""")


@pytest.mark.slow
def test_real_sharded_train_step_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert out["n_devices"] == 8
    assert out["shardings_preserved"]
    import math
    assert math.isfinite(out["loss"])
