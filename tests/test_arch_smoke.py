"""Per-architecture smoke tests: REDUCED variants (<=2 layers, d_model<=512,
<=4 experts) run one forward + one train step + one decode step on CPU,
asserting output shapes and no NaNs. Full configs are exercised only via the
dry-run (deliverable e/f)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, SHAPES, get_arch
from repro.models import transformer as tfm
from repro.train.steps import make_serve_step, make_train_step

B, S = 2, 64


def _batch(cfg, key, loss_kind="lm_xent"):
    s_text = S - cfg.num_patches if cfg.frontend == "vision" else S
    batch = {
        "tokens": jax.random.randint(key, (B, s_text), 0, cfg.vocab),
    }
    if loss_kind == "lm_xent":
        batch["labels"] = jax.random.randint(key, (B, s_text), 0, cfg.vocab)
    else:
        batch["residual"] = jax.random.normal(
            key, (B, s_text, cfg.vocab), jnp.float32) * 0.1
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.num_frames, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_shapes_no_nan(arch, key):
    cfg = get_arch(arch, smoke=True)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    assert cfg.moe_experts <= 4
    params = tfm.init_params(key, cfg)
    batch = _batch(cfg, key)
    kwargs = {}
    if cfg.frontend == "vision":
        kwargs["patches"] = batch["patches"]
    if cfg.is_encoder_decoder:
        kwargs["frames"] = batch["frames"]
    logits, aux = tfm.apply(params, cfg, batch["tokens"], **kwargs)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch, key):
    cfg = get_arch(arch, smoke=True)
    params = tfm.init_params(key, cfg)
    step, opt = make_train_step(cfg, "lm_xent", lr=1e-3)
    state = opt.init(params)
    batch = _batch(cfg, key)
    params2, state, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, params2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_gal_residual_fit_step(arch, key):
    """The paper-faithful local objective trains on every architecture."""
    cfg = get_arch(arch, smoke=True)
    params = tfm.init_params(key, cfg)
    step, opt = make_train_step(cfg, "gal_residual", lr=1e-3)
    state = opt.init(params)
    batch = _batch(cfg, key, loss_kind="gal_residual")
    losses = []
    for _ in range(3):
        params, state, metrics = step(params, state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]       # the residual fit makes progress


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch, key):
    cfg = get_arch(arch, smoke=True)
    params = tfm.init_params(key, cfg)
    serve = make_serve_step(cfg)
    enc = None
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(key, (B, cfg.num_frames, cfg.d_model),
                                   jnp.float32)
        enc = tfm.encode(params, cfg, frames)
    cache = tfm.init_cache(cfg, B, 32, encoder_out=enc)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    for _ in range(3):
        logits, cache = serve(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    assert logits.shape == (B, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


def test_all_ten_archs_registered():
    assert len(ALL_ARCHS) == 10
    families = {get_arch(a).family for a in ALL_ARCHS}
    assert families == {"dense", "moe", "vlm", "hybrid", "ssm", "audio"}


def test_full_configs_match_assignment():
    """Exact numbers from the assignment block."""
    spec = {
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
    }
    for arch, (l, d, h, kv, ff, v) in spec.items():
        cfg = get_arch(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (l, d, h, kv, ff, v), arch
    assert get_arch("dbrx-132b").moe_experts == 16
    assert get_arch("dbrx-132b").moe_topk == 4
    assert get_arch("phi3.5-moe-42b-a6.6b").moe_topk == 2
    assert get_arch("zamba2-2.7b").ssm_state == 64
    assert get_arch("whisper-medium").is_encoder_decoder
    assert get_arch("rwkv6-7b").attention_free


def test_input_shapes_match_assignment():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1
