"""Paper ablation behaviours: weights-vs-average under noise, privacy,
local loss choices, DMS (Sections 4.2, 4.5)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import gal
from repro.core.gal import GALConfig
from repro.core.losses import get_loss, lq_loss
from repro.core.organizations import make_orgs
from repro.core.privacy import apply_privacy, dp_laplace, ip_interval
from repro.data.partition import split_features, split_image_patches
from repro.data.synthetic import (
    make_blobs, make_patch_images, make_regression, train_test_split,
)
from repro.metrics.metrics import accuracy, mad
from repro.models.zoo import ConvNet, Linear, MLP


def test_weights_beat_direct_average_under_noise(rng_np, key):
    """Table 6: assistance weights down-weight noisy orgs; direct average
    does not."""
    ds = make_regression(rng_np, n=400, d=12)
    tr, te = train_test_split(ds, rng_np)
    xs, xs_te = split_features(tr.x, 4), split_features(te.x, 4)
    sigmas = [0.0, 5.0, 0.0, 5.0]   # half the orgs are noisy
    loss = get_loss("mse")
    weighted = gal.fit(
        key, make_orgs(xs, Linear(), noise_sigmas=sigmas), tr.y, loss,
        GALConfig(rounds=4, use_weights=True),
        eval_sets={"test": (xs_te, te.y)}, metric_fn=mad)
    averaged = gal.fit(
        key, make_orgs(xs, Linear(), noise_sigmas=sigmas), tr.y, loss,
        GALConfig(rounds=4, use_weights=False),
        eval_sets={"test": (xs_te, te.y)}, metric_fn=mad)
    assert weighted.history["test_metric"][-1] < \
        averaged.history["test_metric"][-1]
    # noisy orgs get smaller weights in early rounds
    w0 = np.asarray(weighted.weights[0])
    assert w0[0] + w0[2] > w0[1] + w0[3]


def test_weights_downweight_uninformative_orgs(rng_np, key):
    """Tables 19-21: orgs with pure-noise features get small weights."""
    ds = make_regression(rng_np, n=300, d=8)
    xs = split_features(ds.x, 2)
    noise = jnp.asarray(rng_np.standard_normal(xs[1].shape).astype(np.float32))
    res = gal.fit(key, make_orgs([xs[0], noise], Linear()), ds.y,
                  get_loss("mse"), GALConfig(rounds=3))
    w0 = np.asarray(res.weights[0])
    assert w0[0] > w0[1]


@pytest.mark.parametrize("mechanism", ["dp", "ip"])
def test_privacy_enhanced_gal_still_beats_alone(rng_np, key, mechanism):
    """Table 5: GAL_DP / GAL_IP outperform Alone."""
    ds = make_regression(rng_np, n=400, d=12)
    tr, te = train_test_split(ds, rng_np)
    xs, xs_te = split_features(tr.x, 4), split_features(te.x, 4)
    loss = get_loss("mse")
    priv = gal.fit(key, make_orgs(xs, Linear()), tr.y, loss,
                   GALConfig(rounds=5, privacy=mechanism),
                   eval_sets={"test": (xs_te, te.y)}, metric_fn=mad)
    from repro.core import boosting
    alone = boosting.fit_alone(key, xs[0], tr.y, loss, Linear(),
                               GALConfig(rounds=5),
                               eval_sets={"test": ([xs_te[0]], te.y)},
                               metric_fn=mad)
    assert priv.history["test_metric"][-1] < alone.history["test_metric"][-1]


def test_privacy_mechanisms_perturb_residuals(key):
    r = jax.random.normal(key, (64, 3))
    r_dp = dp_laplace(key, r, alpha=1.0)
    r_ip = ip_interval(key, r, n_intervals=1)
    assert float(jnp.max(jnp.abs(r_dp - r))) > 0.0
    assert float(jnp.max(jnp.abs(r_ip - r))) > 0.0
    # IP output takes at most 2 distinct values per column (1 interval split)
    for j in range(3):
        assert len(np.unique(np.asarray(r_ip[:, j]))) <= 2


@pytest.mark.parametrize("q", [1.0, 1.5, 2.0, 4.0])
def test_local_loss_lq_variants(rng_np, key, q):
    """Table 4: all ell_q local losses train; protocol is loss-agnostic."""
    ds = make_blobs(rng_np, n=120, d=10, k=4)
    xs = split_features(ds.x, 4)
    res = gal.fit(key, make_orgs(xs, MLP((16,), epochs=60), local_losses=lq_loss(q)),
                  ds.y, get_loss("xent"), GALConfig(rounds=2))
    assert res.history["train_loss"][-1] < res.history["train_loss"][0]


def test_dms_shares_extractor_and_still_learns(rng_np, key):
    """Sec. 4.2: Deep Model Sharing — one extractor, per-round heads. DMS
    compiles now: auto picks the grouped engine (ConvNet has the
    extractor/head interface), the memory ledger shows the Tx saving, and
    unpack_to_orgs restores the per-org extractor + head-list view."""
    ds = make_patch_images(rng_np, n=96, size=8, k=4)
    tr, te = train_test_split(ds, rng_np)
    xs = split_image_patches(tr.x, 4)
    xs_te = split_image_patches(te.x, 4)
    model = ConvNet(widths=(8, 16), epochs=25)
    orgs = make_orgs(xs, model, dms=True)
    res = gal.fit(key, orgs, tr.y, get_loss("xent"), GALConfig(rounds=3),
                  eval_sets={"test": (xs_te, te.y)}, metric_fn=accuracy)
    assert res.engine == "grouped" and res.plan.has_dms
    # DMS: one extractor per org regardless of rounds (T x memory saving)
    assert res.history["model_memories"] == [4, 4, 4]
    res.unpack_to_orgs()
    for org in orgs:
        assert org._dms_extractor is not None
        assert len(org._dms_heads) == 3
    assert res.history["train_loss"][-1] < res.history["train_loss"][0]


def test_patch_weights_favor_informative_center(rng_np, key):
    """Fig. 4c: central image patches earn larger assistance weights."""
    ds = make_patch_images(rng_np, n=160, size=8, k=4,
                           informative_center=True)
    xs = split_image_patches(ds.x, 4)   # 2x2: all four touch the centre, use 8
    xs = split_image_patches(ds.x, 8)   # 2x4 grid: centre = {1,2,5,6}
    from repro.data.partition import flatten_for_tabular
    xs = flatten_for_tabular(xs)
    res = gal.fit(key, make_orgs(xs, Linear()), ds.y, get_loss("xent"),
                  GALConfig(rounds=2))
    w = np.asarray(res.weights[0])
    centre = w[[1, 2, 5, 6]].sum()
    border = w[[0, 3, 4, 7]].sum()
    assert centre > border, w
