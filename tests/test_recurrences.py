"""Train-path vs decode-path equivalence for the stateful architectures —
the system invariant that makes serve_step trustworthy."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

import repro.models.rwkv as rwkv_lib
import repro.models.ssm as ssm_lib
from repro.configs import get_arch
from repro.models import transformer as tfm


def test_mamba_chunked_train_equals_decode(key):
    cfg = get_arch("zamba2-2.7b", smoke=True)
    s = 16
    old_chunk = ssm_lib.CHUNK
    ssm_lib.CHUNK = 8   # force 2 chunks
    try:
        params = ssm_lib.init_mamba(key, cfg)
        x = jax.random.normal(key, (2, s, cfg.d_model), jnp.float32)
        y_train = ssm_lib.mamba_train(params, cfg, x)
        cache = ssm_lib.init_mamba_cache(cfg, 2, jnp.float32)
        ys = []
        for t in range(s):
            yt, cache = ssm_lib.mamba_decode(params, cfg, x[:, t:t + 1], cache)
            ys.append(yt)
        np.testing.assert_allclose(
            np.asarray(y_train), np.asarray(jnp.concatenate(ys, 1)), atol=1e-4)
    finally:
        ssm_lib.CHUNK = old_chunk


def test_rwkv_factorized_train_equals_decode(key):
    cfg = get_arch("rwkv6-7b", smoke=True)
    s = 64
    params = rwkv_lib.init_rwkv_tmix(key, cfg)
    x = jax.random.normal(key, (2, s, cfg.d_model), jnp.float32)
    y_train = rwkv_lib.rwkv_tmix_train(params, cfg, x)   # chunked factorized
    cache = rwkv_lib.init_rwkv_cache(cfg, 2, jnp.float32)
    c = {"state": cache["state"], "tmix_prev": cache["tmix_prev"]}
    ys = []
    for t in range(s):
        yt, c = rwkv_lib.rwkv_tmix_decode(params, cfg, x[:, t:t + 1], c)
        ys.append(yt)
    np.testing.assert_allclose(
        np.asarray(y_train), np.asarray(jnp.concatenate(ys, 1)), atol=1e-4)


def test_rwkv_factorized_equals_stepscan(key):
    """Chunked factorization == the literal per-step recurrence."""
    cfg = get_arch("rwkv6-7b", smoke=True)
    params = rwkv_lib.init_rwkv_tmix(key, cfg)
    x = jax.random.normal(key, (2, 33, cfg.d_model), jnp.float32)
    # 33 is not divisible by the chunk -> falls back to the per-step scan
    y_scan = rwkv_lib.rwkv_tmix_train(params, cfg, x)
    y_chunk = rwkv_lib.rwkv_tmix_train(params, cfg, x[:, :32])
    np.testing.assert_allclose(
        np.asarray(y_scan[:, :32]), np.asarray(y_chunk), atol=1e-4)


@pytest.mark.parametrize("arch", ["llama3-8b", "qwen3-1.7b", "zamba2-2.7b",
                                  "rwkv6-7b"])
def test_full_model_prefill_vs_decode(arch, key):
    """apply() last-token logits == decode_step after feeding the prefix."""
    cfg = get_arch(arch, smoke=True)
    s = 16
    params = tfm.init_params(key, cfg)
    toks = jax.random.randint(key, (2, s), 0, cfg.vocab)
    logits_full, _ = tfm.apply(params, cfg, toks)
    cache = tfm.init_cache(cfg, 2, s)
    for t in range(s):
        lg, cache = tfm.decode_step(params, cfg, toks[:, t:t + 1], cache)
    np.testing.assert_allclose(np.asarray(logits_full[:, -1]),
                               np.asarray(lg[:, 0]), atol=2e-3)


def test_sliding_window_decode_ring_buffer(key):
    """Windowed decode with a ring cache == full attention restricted to the
    window (the long_500k mechanism)."""
    from dataclasses import replace
    cfg = replace(get_arch("llama3-8b", smoke=True), window=8)
    s = 24
    params = tfm.init_params(key, cfg)
    toks = jax.random.randint(key, (1, s), 0, cfg.vocab)
    logits_full, _ = tfm.apply(params, cfg, toks)   # train path applies window
    cache = tfm.init_cache(cfg, 1, s)               # ring cache of size 8
    assert cache["attn"]["k"].shape[2] == 8
    for t in range(s):
        lg, cache = tfm.decode_step(params, cfg, toks[:, t:t + 1], cache)
    np.testing.assert_allclose(np.asarray(logits_full[:, -1]),
                               np.asarray(lg[:, 0]), atol=2e-3)
