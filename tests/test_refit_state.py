"""Refit regressions: per-org round state must reset at the top of every fit.

Pre-fix, ``Organization.fit_round`` appended to ``_round_params`` forever, so
a second ``gal.fit``/``al.fit`` on the same orgs (rounds sweeps, GAL-after-AL
comparisons) silently offset ``predict_round(t, ...)`` into the FIRST fit's
params. These tests fail on that behavior and pin the reset.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import al, gal
from repro.core.gal import GALConfig
from repro.core.losses import get_loss
from repro.core.organizations import make_orgs
from repro.data.partition import split_features
from repro.data.synthetic import make_regression, train_test_split
from repro.models.zoo import Linear, MLP


def _setting(rng_np, m=4, d=12, n=200):
    ds = make_regression(rng_np, n=n, d=d)
    tr, te = train_test_split(ds, rng_np)
    return split_features(tr.x, m), tr.y, split_features(te.x, m), te.y


def test_gal_refit_twice_matches_fresh_orgs(rng_np, key):
    """Second fit on the SAME orgs == fit on fresh orgs. Pre-fix the reused
    orgs carry 2x rounds of params and predict from the first fit's."""
    xs, y, xs_te, _ = _setting(rng_np)
    loss = get_loss("mse")
    cfg = GALConfig(rounds=3, engine="python")
    orgs = make_orgs(xs, Linear())
    # first fit against a SHIFTED target so its round params are distinct
    gal.fit(key, orgs, y + 3.0, loss, cfg)
    res2 = gal.fit(key, orgs, y, loss, cfg)
    fresh = gal.fit(key, make_orgs(xs, Linear()), y, loss, cfg)
    assert all(org.n_rounds_fit == cfg.rounds for org in orgs)
    np.testing.assert_allclose(np.asarray(res2.predict(xs_te)),
                               np.asarray(fresh.predict(xs_te)),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(res2.history["train_loss"],
                               fresh.history["train_loss"], rtol=1e-6)


def test_al_after_gal_does_not_read_stale_params(rng_np, key):
    """The paper's GAL-vs-AL comparison reuses org lists; AL must start from
    clean round state after a GAL fit (and vice versa)."""
    xs, y, xs_te, _ = _setting(rng_np)
    loss = get_loss("mse")
    orgs = make_orgs(xs, Linear())
    gal.fit(key, orgs, y + 1.0, loss, GALConfig(rounds=2, engine="python"))
    res = al.fit(key, orgs, y, loss, total_steps=4)
    fresh = al.fit(key, make_orgs(xs, Linear()), y, loss, total_steps=4)
    # round-robin over 4 orgs: each org fit exactly once in THIS al.fit
    assert all(org.n_rounds_fit == 1 for org in orgs)
    np.testing.assert_allclose(np.asarray(res.predict(xs_te)),
                               np.asarray(fresh.predict(xs_te)),
                               rtol=1e-5, atol=1e-6)


def test_dms_refit_resets_heads_and_history(rng_np, key):
    """DMS state (shared extractor, per-round heads, residual history) must
    not leak across fits: head count tracks THIS fit's rounds."""
    xs, y, _, _ = _setting(rng_np, n=80)
    loss = get_loss("mse")
    cfg = GALConfig(rounds=2, engine="python")
    orgs = make_orgs(xs, MLP((8,), epochs=5), dms=True)
    gal.fit(key, orgs, y, loss, cfg)
    gal.fit(key, orgs, y, loss, cfg)
    for org in orgs:
        assert len(org._dms_heads) == cfg.rounds
        assert len(org._residual_history) == cfg.rounds


def test_fast_path_results_survive_refit(rng_np, key):
    """Scan/shard results own their stacked per-round params, so a later
    fit on the same orgs (which resets org state) must not change them."""
    xs, y, xs_te, _ = _setting(rng_np)
    loss = get_loss("mse")
    res1 = gal.fit(key, make_orgs(xs, Linear()), y, loss,
                   GALConfig(rounds=3, engine="scan"))
    orgs = res1.orgs
    p1 = np.asarray(res1.predict(xs_te))
    gal.fit(key, orgs, y + 5.0, loss, GALConfig(rounds=2, engine="python"))
    np.testing.assert_array_equal(np.asarray(res1.predict(xs_te)), p1)


def test_dms_memory_ledger_matches_protocol_oracle(rng_np, key):
    """history["model_memories"] equals protocol_sim's Table-14 accounting
    EXACTLY on every engine: DMS orgs hold one live extractor each (the
    Sec. 5 Tx saving), fresh-fit orgs accumulate one model per round."""
    from repro.core.protocol_sim import gal_cost, gal_model_memories
    xs, y, _, _ = _setting(rng_np, n=80)
    loss = get_loss("mse")
    rounds, m = 3, 4
    for engine in ("python", "grouped"):
        res = gal.fit(key, make_orgs(xs, MLP((8,), epochs=4), dms=True), y,
                      loss, GALConfig(rounds=rounds, engine=engine))
        want = gal_cost(y.shape[0], y.shape[-1], m, rounds,
                        dms=True).model_memories
        assert res.history["model_memories"][-1] == want, engine
        assert res.history["model_memories"] == [m] * rounds, engine
    for engine in ("python", "scan"):
        res = gal.fit(key, make_orgs(xs, Linear()), y, loss,
                      GALConfig(rounds=rounds, engine=engine))
        want = gal_cost(y.shape[0], y.shape[-1], m, rounds,
                        dms=False).model_memories
        assert res.history["model_memories"][-1] == want, engine
        assert res.history["model_memories"] == \
            gal_model_memories(rounds, [False] * m), engine
    # mixed DMS + fresh-fit orgs: per-org accounting, engine-independent
    mix = lambda: make_orgs(  # noqa: E731
        xs, [MLP((8,), epochs=4), MLP((8,), epochs=4), Linear(), Linear()],
        dms=[True, True, False, False])
    for engine in ("python", "grouped"):
        res = gal.fit(key, mix(), y, loss,
                      GALConfig(rounds=rounds, engine=engine))
        assert res.history["model_memories"] == [4, 6, 8], engine


def test_grouped_dms_refit_resets_stacked_heads(rng_np, key):
    """Refit-after-reset on the grouped DMS engine: a second fit on the
    SAME orgs reproduces a fresh fit exactly (reset_round_state zeroes the
    stacked heads / extractor / residual history), and unpack_to_orgs
    restores per-org DMS state that predict_round can replay."""
    xs, y, xs_te, _ = _setting(rng_np, n=80)
    loss = get_loss("mse")
    cfg = GALConfig(rounds=2, engine="grouped")
    orgs = make_orgs(xs, MLP((8,), epochs=4), dms=True)
    gal.fit(key, orgs, y + 3.0, loss, cfg)       # pollute with a first fit
    res2 = gal.fit(key, orgs, y, loss, cfg)
    fresh = gal.fit(key, make_orgs(xs, MLP((8,), epochs=4), dms=True), y,
                    loss, cfg)
    np.testing.assert_allclose(np.asarray(res2.predict(xs_te)),
                               np.asarray(fresh.predict(xs_te)),
                               rtol=1e-5, atol=1e-6)
    # the fused fit never touches live org state...
    assert all(org.n_rounds_fit == 0 for org in orgs)
    assert all(org._dms_extractor is None for org in orgs)
    # ...until unpack_to_orgs restores extractor + per-round head list
    res2.unpack_to_orgs()
    assert all(len(org._dms_heads) == res2.rounds for org in orgs)
    assert all(org._dms_extractor is not None for org in orgs)
    from repro.data.partition import pad_and_stack
    xe_stack, _ = pad_and_stack(xs_te, pad_to=res2.group_pads[0])
    legacy = res2.predict_legacy(list(xe_stack))
    np.testing.assert_allclose(np.asarray(legacy),
                               np.asarray(res2.predict(xs_te)),
                               rtol=1e-4, atol=1e-5)


def test_scan_refit_on_same_orgs(rng_np, key):
    """The fused engines never touch org state during fit, but a preceding
    python fit (or unpack_to_orgs) must not leak into a later unpack."""
    xs, y, xs_te, _ = _setting(rng_np)
    loss = get_loss("mse")
    orgs = make_orgs(xs, Linear())
    gal.fit(key, orgs, y + 2.0, loss, GALConfig(rounds=4, engine="python"))
    res = gal.fit(key, orgs, y, loss, GALConfig(rounds=2, engine="scan"))
    assert all(org.n_rounds_fit == 0 for org in orgs)  # reset, scan is pure
    res.unpack_to_orgs()
    assert all(org.n_rounds_fit == res.rounds for org in orgs)
