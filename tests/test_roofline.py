"""Loop-aware HLO accounting: exact FLOPs on known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (
    Hardware, collective_bytes_from_hlo, dominant_term, model_flops,
    roofline_terms,
)
from repro.roofline.hlo_stats import analyze
from repro.configs import SHAPES, get_arch


def test_scan_trip_counts_multiply_flops():
    n, trips = 64, 5
    w = jnp.eye(n, dtype=jnp.float32)

    def f(x):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=trips)
        return y

    compiled = jax.jit(f).lower(jnp.ones((n, n), jnp.float32)).compile()
    st = analyze(compiled.as_text())
    assert st.flops == pytest.approx(trips * 2 * n ** 3)
    # XLA's own cost model counts the body once (the undercount we correct);
    # cost_analysis returns a per-device list on some jax versions
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    assert cost["flops"] < st.flops


def test_nested_scan_trip_products():
    n, outer, inner = 32, 3, 4
    w = jnp.eye(n, dtype=jnp.float32)

    def f(x):
        def obody(c, _):
            def ibody(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(ibody, c, None, length=inner)
            return ci, None
        y, _ = jax.lax.scan(obody, x, None, length=outer)
        return y

    compiled = jax.jit(f).lower(jnp.ones((n, n), jnp.float32)).compile()
    st = analyze(compiled.as_text())
    assert st.flops == pytest.approx(outer * inner * 2 * n ** 3, rel=0.01)


def test_single_dot_flops_and_bytes():
    a = jnp.ones((128, 256), jnp.float32)
    b = jnp.ones((256, 64), jnp.float32)
    compiled = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    st = analyze(compiled.as_text())
    assert st.flops == pytest.approx(2 * 128 * 256 * 64)
    assert st.bytes_accessed >= (128 * 256 + 256 * 64 + 128 * 64) * 4


def test_collective_regex_parses_kinds():
    fake = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[64]{0} all-reduce(%y), to_apply=%add
  %rs = f32[32]{0} reduce-scatter(%z), dimensions={0}
  %a2a = bf16[4,4]{1,0} all-to-all(%w), dimensions={0}
"""
    out = collective_bytes_from_hlo(fake)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 64 * 4
    assert out["reduce-scatter"] == 32 * 4
    assert out["all-to-all"] == 16 * 2


def test_roofline_terms_and_dominance():
    terms = roofline_terms(
        {"flops": 1e12, "bytes accessed": 1e9},
        {"all-gather": 1e8}, n_chips=256)
    hw = Hardware()
    assert terms["t_compute"] == pytest.approx(1e12 / hw.peak_flops)
    assert terms["t_memory"] == pytest.approx(1e9 / hw.hbm_bw)
    assert terms["t_collective"] == pytest.approx(1e8 / hw.link_bw)
    assert dominant_term(terms) == "t_compute"


def test_model_flops_moe_uses_active_params():
    dense = get_arch("llama3-8b")
    moe = get_arch("dbrx-132b")
    shape = SHAPES["train_4k"]
    assert moe.active_param_count() < moe.param_count()
    # dbrx: 16 experts top-4 -> most params inactive per token
    ratio = moe.active_param_count() / moe.param_count()
    assert 0.2 < ratio < 0.5
    assert model_flops(dense, shape) == pytest.approx(
        6.0 * dense.param_count() * shape.global_batch * shape.seq_len)


def test_param_counts_match_public_sizes():
    """Analytic counts land near the models' public sizes."""
    expect = {
        "llama3-8b": 8.0e9, "dbrx-132b": 132e9, "pixtral-12b": 12e9,
        "stablelm-1.6b": 1.6e9, "granite-8b": 8e9, "qwen3-1.7b": 1.7e9,
        "rwkv6-7b": 7e9, "zamba2-2.7b": 2.7e9,
        "phi3.5-moe-42b-a6.6b": 42e9,
    }
    for arch, n in expect.items():
        got = get_arch(arch).param_count()
        assert 0.55 * n < got < 1.7 * n, (arch, got, n)
