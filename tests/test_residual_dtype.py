"""Compressed residual broadcast (``GALConfig(residual_dtype="bf16")``).

The knob is a WIRE property of Algorithm 1's step-2 broadcast: the
privatized residual is cast to bfloat16 before it leaves Alice and upcast
on arrival, so every engine sees the identical rounded values and the
draw-for-draw cross-engine contract survives compression. The ledger books
the reduced exact bytes (2-byte residual width); the fitted-value gather
is untouched. The fp32 default must stay bitwise what it always was.
"""
import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import gal
from repro.core.gal import GALConfig
from repro.core.losses import get_loss
from repro.core.membership import membership_comm_ledger
from repro.core.organizations import make_orgs
from repro.core.protocol_sim import gal_round_bytes
from repro.data.partition import split_features
from repro.data.synthetic import make_regression, train_test_split
from repro.models.zoo import Linear

M = 4


def _setting(rng_np, n=240, d=12):
    ds = make_regression(rng_np, n=n, d=d)
    tr, te = train_test_split(ds, rng_np)
    return split_features(tr.x, M), tr.y, split_features(te.x, M), te.y


def _fit(key, xs, y, cfg, **kw):
    return gal.fit(key, make_orgs(xs, Linear()), y, get_loss("mse"), cfg,
                   **kw)


# ------------------------------------------------------------------- ledger

def test_ledger_broadcast_exactly_halved():
    b32, g32 = gal_round_bytes(1000, 3, 7, eval_ns=(100, 50))
    b16, g16 = gal_round_bytes(1000, 3, 7, eval_ns=(100, 50),
                               resid_dtype_bytes=2)
    assert b32 == (7 - 1) * 1000 * 3 * 4
    assert b16 * 2 == b32
    assert g16 == g32 == 7 * 1000 * 3 * 4 + 7 * 100 * 3 * 4 + 7 * 50 * 3 * 4


def test_engine_ledger_halves_broadcast_only(rng_np, key):
    xs, y, xs_te, y_te = _setting(rng_np)
    ev = {"test": (xs_te, y_te)}
    r32 = _fit(key, xs, y, GALConfig(rounds=3, engine="scan"), eval_sets=ev)
    r16 = _fit(key, xs, y, GALConfig(rounds=3, engine="scan",
                                     residual_dtype="bf16"), eval_sets=ev)
    assert [b * 2 for b in r16.history["comm_broadcast_bytes"]] == \
        r32.history["comm_broadcast_bytes"]
    assert r16.history["comm_gather_bytes"] == \
        r32.history["comm_gather_bytes"]


def test_membership_ledger_threads_resid_width():
    sched = np.array([[True, True, False], [True, True, True]])
    b16, g16 = membership_comm_ledger(sched, 100, 2, eval_ns=(10,),
                                      resid_dtype_bytes=2)
    b32, g32 = membership_comm_ledger(sched, 100, 2, eval_ns=(10,))
    assert [b * 2 for b in b16] == b32
    assert g16 == g32


# ----------------------------------------------------------- engine parity

def test_python_scan_draw_for_draw_under_bf16(rng_np, key):
    xs, y, xs_te, y_te = _setting(rng_np)
    cfg = GALConfig(rounds=4, residual_dtype="bf16")
    res_py = _fit(key, xs, y, dataclasses.replace(cfg, engine="python"),
                  eval_sets={"test": (xs_te, y_te)})
    res_sc = _fit(key, xs, y, dataclasses.replace(cfg, engine="scan"),
                  eval_sets={"test": (xs_te, y_te)})
    np.testing.assert_allclose(res_sc.etas, res_py.etas, rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.stack(res_sc.weights),
                               np.stack(res_py.weights), atol=1e-4)
    np.testing.assert_allclose(res_sc.history["train_loss"],
                               res_py.history["train_loss"],
                               rtol=1e-3, atol=1e-4)


def test_fp32_default_and_alias_bitwise_identical(rng_np, key):
    xs, y, _, _ = _setting(rng_np)
    res_def = _fit(key, xs, y, GALConfig(rounds=3, engine="scan"))
    res_fp = _fit(key, xs, y, GALConfig(rounds=3, engine="scan",
                                        residual_dtype="fp32"))
    assert res_def.etas == res_fp.etas
    assert res_def.history["train_loss"] == res_fp.history["train_loss"]


def test_bf16_actually_reaches_the_wire(rng_np, key):
    """The cast must change SOMETHING — otherwise the knob is dead code."""
    xs, y, _, _ = _setting(rng_np)
    res32 = _fit(key, xs, y, GALConfig(rounds=3, engine="scan"))
    res16 = _fit(key, xs, y, GALConfig(rounds=3, engine="scan",
                                       residual_dtype="bf16"))
    assert res32.history["train_loss"] != res16.history["train_loss"]


def test_bf16_accuracy_gate(rng_np, key):
    """The compressed run must land within 2% relative of the fp32 final
    train loss — the acceptance gate for shipping bf16 as a default-off
    optimization."""
    xs, y, _, _ = _setting(rng_np)
    res32 = _fit(key, xs, y, GALConfig(rounds=5, engine="scan"))
    res16 = _fit(key, xs, y, GALConfig(rounds=5, engine="scan",
                                       residual_dtype="bf16"))
    f32, f16 = res32.history["train_loss"][-1], res16.history["train_loss"][-1]
    assert abs(f16 - f32) <= 0.02 * abs(f32) + 1e-6


def test_unknown_residual_dtype_rejected(rng_np, key):
    xs, y, _, _ = _setting(rng_np)
    with pytest.raises(ValueError, match="residual_dtype"):
        _fit(key, xs, y, GALConfig(rounds=1, residual_dtype="f8"))
