"""Checkpoint round-trips + GAL round resumability.

Covers both pytree layers: the ``like``-templated exact round-trip
(treedef + dtypes authoritative, bf16 leaves via uint16 views) and the
self-describing load (``like=None``) the artifact reader uses — structure
rebuilt from the flattened key paths alone, which must hold for the
engines' stacked group-param pytrees (nested dicts, lists of layer dicts,
mixed dtypes including bf16)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import GALCheckpoint, load_pytree, save_pytree


def test_pytree_roundtrip(tmp_path, key):
    tree = {
        "layers": [{"w": jax.random.normal(key, (4, 8)),
                    "b": jnp.zeros((8,), jnp.bfloat16)}],
        "step": jnp.asarray(7, jnp.int32),
        "nested": {"a": (jnp.ones((2, 2)), jnp.arange(3))},
    }
    save_pytree(tmp_path / "ck.npz", tree)
    loaded = load_pytree(tmp_path / "ck.npz", tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(loaded)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def _stacked_group_params(key):
    """A realistic compiled-engine group-params pytree: per-round stacked
    leaves (T, M_g, ...) in nested dicts/lists, one bf16 leaf (the dtype
    npz cannot hold natively) and one int leaf (stump feature indices)."""
    k1, k2 = jax.random.split(key)
    return {
        "g0": {"w": jax.random.normal(k1, (3, 2, 5, 4)),
               "b": jnp.zeros((3, 2, 4), jnp.bfloat16)},
        "g1": {"layers": [{"w": jax.random.normal(k2, (3, 2, 4, 8))},
                          {"w": jnp.ones((3, 2, 8, 1))}],
               "feat": jnp.arange(6, dtype=jnp.int32).reshape(3, 2)},
    }


def test_stacked_group_params_roundtrip_with_treedef(tmp_path, key):
    tree = _stacked_group_params(key)
    save_pytree(tmp_path / "gp.npz", tree)
    loaded = load_pytree(tmp_path / "gp.npz", tree)
    assert (jax.tree_util.tree_structure(loaded)
            == jax.tree_util.tree_structure(tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(loaded)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_self_describing_load_rebuilds_structure(tmp_path, key):
    """load_pytree(path) with NO template — the artifact reader's path —
    must rebuild nested dicts and lists (and bf16 dtypes) from the
    flattened key paths alone, bitwise."""
    tree = _stacked_group_params(key)
    save_pytree(tmp_path / "gp.npz", tree)
    loaded = load_pytree(tmp_path / "gp.npz")
    assert set(loaded) == {"g0", "g1"}
    assert isinstance(loaded["g1"]["layers"], list)
    assert len(loaded["g1"]["layers"]) == 2
    assert loaded["g0"]["b"].dtype == jnp.bfloat16
    assert loaded["g1"]["feat"].dtype == jnp.int32
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(loaded)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_self_describing_load_keeps_empty_containers(tmp_path, key):
    """Zero-leaf nodes (empty dict/list, None) must survive the
    template-free load — silently dropping them would shift list indices
    and lose dict keys (e.g. an empty DMS state in the resume carry)."""
    tree = {"mid": [jnp.arange(2), {}, jnp.ones((2,))],
            "state": {}, "maybe": None, "tail": [jnp.zeros((1,))]}
    save_pytree(tmp_path / "e.npz", tree)
    loaded = load_pytree(tmp_path / "e.npz")
    assert loaded["state"] == {} and loaded["maybe"] is None
    assert len(loaded["mid"]) == 3 and loaded["mid"][1] == {}
    np.testing.assert_array_equal(np.asarray(loaded["mid"][2]),
                                  np.ones((2,)))
    save_pytree(tmp_path / "root.npz", {})
    assert load_pytree(tmp_path / "root.npz") == {}


def test_self_describing_load_bare_leaf(tmp_path, key):
    x = jax.random.normal(key, (4, 3))
    save_pytree(tmp_path / "leaf.npz", x)
    np.testing.assert_array_equal(np.asarray(load_pytree(tmp_path
                                                         / "leaf.npz")),
                                  np.asarray(x))


def test_gal_round_checkpoint_resume(tmp_path, key):
    ck = GALCheckpoint(tmp_path / "gal")
    assert ck.latest_round() == -1
    params_t0 = [{"w": jax.random.normal(key, (3, 2))}, {"w": jnp.ones((4, 2))}]
    ck.save_round(0, eta=1.5, weights=jnp.asarray([0.25, 0.75]),
                  org_params=params_t0)
    ck.save_round(1, eta=0.8, weights=jnp.asarray([0.5, 0.5]),
                  org_params=params_t0)
    assert ck.latest_round() == 1
    meta = ck.load_round_meta(1)
    assert meta["eta"] == 0.8
    restored = ck.load_org_params(0, 0, params_t0[0])
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(params_t0[0]["w"]))
