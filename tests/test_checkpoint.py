"""Checkpoint round-trips + GAL round resumability."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import GALCheckpoint, load_pytree, save_pytree


def test_pytree_roundtrip(tmp_path, key):
    tree = {
        "layers": [{"w": jax.random.normal(key, (4, 8)),
                    "b": jnp.zeros((8,), jnp.bfloat16)}],
        "step": jnp.asarray(7, jnp.int32),
        "nested": {"a": (jnp.ones((2, 2)), jnp.arange(3))},
    }
    save_pytree(tmp_path / "ck.npz", tree)
    loaded = load_pytree(tmp_path / "ck.npz", tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(loaded)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_gal_round_checkpoint_resume(tmp_path, key):
    ck = GALCheckpoint(tmp_path / "gal")
    assert ck.latest_round() == -1
    params_t0 = [{"w": jax.random.normal(key, (3, 2))}, {"w": jnp.ones((4, 2))}]
    ck.save_round(0, eta=1.5, weights=jnp.asarray([0.25, 0.75]),
                  org_params=params_t0)
    ck.save_round(1, eta=0.8, weights=jnp.asarray([0.5, 0.5]),
                  org_params=params_t0)
    assert ck.latest_round() == 1
    meta = ck.load_round_meta(1)
    assert meta["eta"] == 0.8
    restored = ck.load_org_params(0, 0, params_t0[0])
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(params_t0[0]["w"]))
