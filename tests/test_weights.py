"""Assistance-weight fit: the rng argument must actually matter.

Pre-fix, ``fit_weights`` accepted ``rng`` and every engine carefully threaded
``fold_in(k_round, 29)`` into it, but theta was initialized to zeros — the
step-4 leg of the engines' RNG-discipline parity claim was vacuous. The key
now seeds the softmax logits; these tests pin that choice.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.losses import lq_loss
from repro.core.weights import fit_weights, uniform_weights


def _problem(key, m=4, n=64, k=2):
    r = jax.random.normal(key, (n, k))
    preds = jax.random.normal(jax.random.fold_in(key, 1), (m, n, k))
    return r, preds


def test_same_key_is_deterministic(key):
    r, preds = _problem(key)
    w1 = fit_weights(jax.random.fold_in(key, 29), r, preds, lq_loss(2.0))
    w2 = fit_weights(jax.random.fold_in(key, 29), r, preds, lq_loss(2.0))
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))


def test_key_seeds_theta_init(key):
    """Different keys -> different inits (visible before Adam converges)."""
    r, preds = _problem(key)
    w_a = fit_weights(jax.random.PRNGKey(1), r, preds, lq_loss(2.0), epochs=0)
    w_b = fit_weights(jax.random.PRNGKey(2), r, preds, lq_loss(2.0), epochs=0)
    assert not np.allclose(np.asarray(w_a), np.asarray(w_b))


def test_init_is_near_uniform_jitter(key):
    """The seed is a SMALL jitter around the uniform-weights start, so the
    optimized weights stay key-insensitive after convergence."""
    r, preds = _problem(key)
    w0 = fit_weights(key, r, preds, lq_loss(2.0), epochs=0)
    np.testing.assert_allclose(np.asarray(w0), np.asarray(uniform_weights(4)),
                               atol=0.02)
    w_a = fit_weights(jax.random.PRNGKey(1), r, preds, lq_loss(2.0))
    w_b = fit_weights(jax.random.PRNGKey(2), r, preds, lq_loss(2.0))
    np.testing.assert_allclose(np.asarray(w_a), np.asarray(w_b), atol=1e-3)


def test_simplex_preserved(key):
    r, preds = _problem(key, m=5)
    w = np.asarray(fit_weights(key, r, preds, lq_loss(2.0), epochs=30))
    assert np.all(w >= 0)
    np.testing.assert_allclose(w.sum(), 1.0, atol=1e-5)
