"""Scalar line-search behavior pinned without the hypothesis dependency."""
import numpy as np
import jax.numpy as jnp

from repro.optim.lbfgs import golden_section, line_search


def test_golden_section_one_eval_per_iteration():
    """The surviving probe's value is carried through the loop: the traced
    body must contain exactly ONE fn evaluation (plus two seeding the
    bracket), not two — each eval is a full ensemble-loss pass in the GAL
    engines. lax.fori_loop traces its body once, so trace-time call counts
    expose the per-iteration cost."""
    calls = []

    def fn(x):
        calls.append(1)
        return (x - 1.3) ** 2

    x = golden_section(fn, 0.0, 3.0, iters=30)
    assert len(calls) == 3, f"expected 2 seed + 1 body evals, saw {len(calls)}"
    assert abs(float(x) - 1.3) < 1e-3


def test_golden_section_converges_like_before():
    """Interval still shrinks by 1/phi per iteration (the carried probe sits
    at the golden point of the shrunk interval)."""
    for a in (-2.0, 0.0, 1.7, 4.2):
        got = float(golden_section(lambda x: (x - a) ** 2 + 1.0,
                                   a - 3.0, a + 3.0, iters=50))
        # f32 golden section resolves a quadratic min to ~sqrt(eps)*scale
        assert abs(got - a) < 5e-3, (got, a)
    # asymmetric / non-quadratic
    got = float(golden_section(lambda x: jnp.abs(x - 0.8) + 0.1 * x,
                               0.0, 5.0, iters=60))
    assert abs(got - 0.8) < 1e-3


def test_line_search_golden_path_unchanged():
    eta = float(line_search(lambda e: jnp.mean((e - 1.7) ** 2),
                            method="golden"))
    assert abs(eta - 1.7) < 1e-2
