"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp ref oracles
(interpret=True executes the kernel bodies on CPU)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
pytest.importorskip("hypothesis")  # optional dev dep; skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.ops import flash_attention, residual_xent


@pytest.mark.parametrize("t,v", [(7, 300), (128, 512), (130, 513), (256, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_residual_xent_matches_ref(t, v, dtype, key):
    logits = (jax.random.normal(key, (t, v), jnp.float32) * 3).astype(dtype)
    labels = jax.random.randint(key, (t,), 0, v)
    out = residual_xent(logits, labels)
    want = ref.residual_xent_ref(logits, labels)
    tol = 1e-5 if dtype == jnp.float32 else 5e-3
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=tol)


def test_residual_xent_batched_shape(key):
    logits = jax.random.normal(key, (2, 16, 300))
    labels = jax.random.randint(key, (2, 16), 0, 300)
    out = residual_xent(logits, labels)
    assert out.shape == (2, 16, 300)
    # rows sum to ~0: onehot sums to 1, softmax sums to 1
    np.testing.assert_allclose(np.asarray(jnp.sum(out, -1)), 0.0, atol=1e-4)


@settings(max_examples=12, deadline=None)
@given(
    t=st.integers(1, 200),
    v=st.integers(2, 700),
    scale=st.floats(0.1, 8.0),
)
def test_residual_xent_property(t, v, scale):
    """Property: r = onehot - softmax for arbitrary shapes/scales."""
    key = jax.random.PRNGKey(t * 1000 + v)
    logits = jax.random.normal(key, (t, v)) * scale
    labels = jax.random.randint(key, (t,), 0, v)
    out = residual_xent(logits, labels)
    want = ref.residual_xent_ref(logits, labels)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("b,s,h,kv,hd,causal,window", [
    (2, 128, 4, 2, 64, True, None),
    (1, 200, 4, 4, 32, True, 64),
    (2, 256, 8, 2, 64, False, None),
    (1, 130, 2, 1, 128, True, 32),
])
def test_flash_attention_matches_ref(b, s, h, kv, hd, causal, window, key):
    q = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd)) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 3), (b, s, kv, hd))
    out = flash_attention(q, k, v, causal=causal, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype, key):
    b, s, h, kv, hd = 1, 128, 4, 2, 64
    q = (jax.random.normal(key, (b, s, h, hd)) * 0.5).astype(dtype)
    k = (jax.random.normal(key, (b, s, kv, hd)) * 0.5).astype(dtype)
    v = jax.random.normal(key, (b, s, kv, hd)).astype(dtype)
    out = flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=3e-2)


@settings(max_examples=8, deadline=None)
@given(
    s=st.integers(2, 160),
    h_pow=st.integers(0, 3),
    g=st.sampled_from([1, 2, 4]),
    causal=st.booleans(),
)
def test_flash_attention_property(s, h_pow, g, causal):
    kv = 2 ** h_pow
    h = kv * g
    hd = 32
    key = jax.random.PRNGKey(s * 31 + h)
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, s, h, hd)) * 0.3
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, s, kv, hd)) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 3), (1, s, kv, hd))
    out = flash_attention(q, k, v, causal=causal)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_chunked_attention_matches_flash_ref(key):
    """The pure-JAX chunked (GSPMD-partitionable) path == the kernel's math."""
    from repro.models.attention import _chunked_attention
    b, s, h, hd = 1, 256, 4, 32
    q = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd)) * 0.4
    k = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd)) * 0.4
    v = jax.random.normal(jax.random.fold_in(key, 3), (b, s, h, hd))
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    out = _chunked_attention(q, k, v, positions, causal=True, window=None,
                             chunk=64, batch=b, heads=h)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
