"""Pallas kernel validation: deterministic shape/dtype sweeps vs the
pure-jnp ref oracles AND the generic autodiff ``Loss.residual`` path
(interpret=True executes the kernel bodies on CPU). The hypothesis property
sweeps live in ``tests/test_kernel_properties.py`` (optional dev dep), so
everything here always runs."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.losses import CrossEntropyLoss, autodiff_residual
from repro.kernels import ref
from repro.kernels.ops import flash_attention, residual_xent
from repro.kernels.residual_xent import BT, BV


@pytest.mark.parametrize("t,v", [(7, 300), (128, 512), (130, 513), (256, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_residual_xent_matches_ref(t, v, dtype, key):
    logits = (jax.random.normal(key, (t, v), jnp.float32) * 3).astype(dtype)
    labels = jax.random.randint(key, (t,), 0, v)
    out = residual_xent(logits, labels)
    want = ref.residual_xent_ref(logits, labels)
    tol = 1e-5 if dtype == jnp.float32 else 5e-3
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=tol)


def test_residual_xent_batched_shape(key):
    logits = jax.random.normal(key, (2, 16, 300))
    labels = jax.random.randint(key, (2, 16), 0, 300)
    out = residual_xent(logits, labels)
    assert out.shape == (2, 16, 300)
    # rows sum to ~0: onehot sums to 1, softmax sums to 1
    np.testing.assert_allclose(np.asarray(jnp.sum(out, -1)), 0.0, atol=1e-4)


@pytest.mark.parametrize("b,s,h,kv,hd,causal,window", [
    (2, 128, 4, 2, 64, True, None),
    (1, 200, 4, 4, 32, True, 64),
    (2, 256, 8, 2, 64, False, None),
    (1, 130, 2, 1, 128, True, 32),
])
def test_flash_attention_matches_ref(b, s, h, kv, hd, causal, window, key):
    q = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd)) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 3), (b, s, kv, hd))
    out = flash_attention(q, k, v, causal=causal, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype, key):
    b, s, h, kv, hd = 1, 128, 4, 2, 64
    q = (jax.random.normal(key, (b, s, h, hd)) * 0.5).astype(dtype)
    k = (jax.random.normal(key, (b, s, kv, hd)) * 0.5).astype(dtype)
    v = jax.random.normal(key, (b, s, kv, hd)).astype(dtype)
    out = flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=3e-2)


# ---- residual_xent vs the generic autodiff Loss.residual path ----------
#
# The Pallas kernel IS CrossEntropyLoss.residual at LM scale (vocab >=
# XENT_KERNEL_MIN_CLASSES routes through it automatically); the ground
# truth for both is the autodiff fallback -d/dF sum(per_sample) that any
# custom Loss compiles through.

def _autodiff_oracle(logits, labels):
    y = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    return autodiff_residual(CrossEntropyLoss(), y, logits)


@pytest.mark.parametrize("t,v", [(7, 300), (BT + 2, BV + 1), (64, 2 * BV)])
def test_residual_xent_matches_autodiff_loss_residual(t, v, key):
    logits = jax.random.normal(key, (t, v)) * 3
    labels = jax.random.randint(key, (t,), 0, v)
    out = residual_xent(logits, labels)
    want = _autodiff_oracle(logits, labels)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_residual_xent_tied_max_across_tiles(key):
    """Tied maxima spanning TWO vocab tiles: the online (max, sumexp) carry
    must count both ties, or softmax mass is lost at the seam."""
    t, v = 9, BV + 200                    # two vocab tiles
    logits = jax.random.normal(key, (t, v))
    big = jnp.max(logits) + 5.0
    # the row max appears in tile 0 AND tile 1, exactly tied
    logits = logits.at[:, 17].set(big).at[:, BV + 50].set(big)
    labels = jnp.asarray([17, BV + 50, 0] * 3)
    out = residual_xent(logits, labels)
    want = _autodiff_oracle(logits, labels)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
    # the two tied columns split the top softmax mass equally
    np.testing.assert_allclose(np.asarray(out[2, 17]),
                               np.asarray(out[2, BV + 50]), atol=1e-6)


def test_residual_xent_padded_vocab_tail(key):
    """v one past a tile edge: the tail tile is almost all -inf padding.
    The padded columns must neither leak mass into the softmax nor match
    the -1 pad labels; labels IN the tail column still one-hot correctly."""
    t, v = BT + 3, BV + 1                 # tail tile = 1 real column
    logits = jax.random.normal(key, (t, v)) * 2
    labels = jnp.full((t,), v - 1, jnp.int32)   # every label in the tail
    out = residual_xent(logits, labels)
    want = _autodiff_oracle(logits, labels)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
    np.testing.assert_allclose(np.asarray(jnp.sum(out, -1)), 0.0, atol=1e-4)


def test_xent_loss_routes_through_kernel_at_lm_scale(key, monkeypatch):
    """CrossEntropyLoss.residual picks the Pallas kernel automatically at
    vocab >= XENT_KERNEL_MIN_CLASSES (on the kernel backends — widened to
    this host's backend here so the dispatch runs in interpret mode) and
    stays equal to the closed form y - softmax(F) and the autodiff oracle."""
    from repro.core import losses as losses_mod
    from repro.core.losses import XENT_KERNEL_MIN_CLASSES
    monkeypatch.setattr(losses_mod, "XENT_KERNEL_BACKENDS",
                        ("tpu", jax.default_backend()))
    t, v = 6, XENT_KERNEL_MIN_CLASSES
    logits = jax.random.normal(key, (t, v)) * 2
    labels = jax.random.randint(key, (t,), 0, v)
    y = jax.nn.one_hot(labels, v, dtype=jnp.float32)
    out = CrossEntropyLoss().residual(y, logits)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(y - jax.nn.softmax(logits, -1)),
        atol=2e-5)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(_autodiff_oracle(logits, labels)),
        atol=2e-5)
    # below the threshold the closed form answers directly (same numbers)
    small = CrossEntropyLoss().residual(y[:, :300], logits[:, :300])
    np.testing.assert_allclose(
        np.asarray(small),
        np.asarray(y[:, :300] - jax.nn.softmax(logits[:, :300], -1)),
        atol=2e-5)


def test_xent_kernel_route_exact_for_soft_targets(key, monkeypatch):
    """Label-smoothed (non-one-hot) targets must stay exact on the kernel
    route: the y - onehot(argmax y) correction recovers r = y - softmax
    exactly, so LM-scale smoothing never silently optimizes hard labels."""
    from repro.core import losses as losses_mod
    from repro.core.losses import XENT_KERNEL_MIN_CLASSES
    monkeypatch.setattr(losses_mod, "XENT_KERNEL_BACKENDS",
                        ("tpu", jax.default_backend()))
    t, v = 5, XENT_KERNEL_MIN_CLASSES
    logits = jax.random.normal(key, (t, v)) * 2
    labels = jax.random.randint(key, (t,), 0, v)
    eps = 0.1
    y_soft = (1 - eps) * jax.nn.one_hot(labels, v) + eps / v
    out = CrossEntropyLoss().residual(y_soft, logits)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(y_soft - jax.nn.softmax(logits, -1)),
        atol=2e-5)


def test_chunked_attention_matches_flash_ref(key):
    """The pure-JAX chunked (GSPMD-partitionable) path == the kernel's math."""
    from repro.models.attention import _chunked_attention
    b, s, h, hd = 1, 256, 4, 32
    q = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd)) * 0.4
    k = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd)) * 0.4
    v = jax.random.normal(jax.random.fold_in(key, 3), (b, s, h, hd))
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    out = _chunked_attention(q, k, v, positions, causal=True, window=None,
                             chunk=64, batch=b, heads=h)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
