"""Prediction-stage serving: batched single-token decode against a KV/state
cache for any assigned architecture — the step the decode_32k / long_500k
dry-run shapes lower.

GAL context: in the paper's Prediction Stage each org serves its local
per-round models and Alice assembles F^T = F^0 + sum_t eta_t sum_m w_mt f_mt.
Here one org serves its model and reports logits; the (eta, w) assembly is a
dot product on Alice's side (shown at the end).

Run: PYTHONPATH=src python examples/serve_decode.py --arch zamba2-2.7b
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_arch
from repro.models import transformer as tfm
from repro.train.steps import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b", choices=ALL_ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(key, cfg)
    serve_step = jax.jit(make_serve_step(cfg))

    enc = None
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(
            key, (args.batch, cfg.num_frames, cfg.d_model), jnp.float32)
        enc = tfm.encode(params, cfg, frames)
    cache = tfm.init_cache(cfg, args.batch, args.cache_len, encoder_out=enc)

    tok = jax.random.randint(key, (args.batch, 1), 0, cfg.vocab)
    # warmup + timed decode loop
    logits, cache = serve_step(params, cache, tok)
    t0 = time.perf_counter()
    etas, weights = [], []
    f_alice = jnp.zeros((args.batch, cfg.vocab))
    for step in range(args.steps):
        logits, cache = serve_step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        # Alice-side assembly with this round's (eta, w) — one org shown
        f_alice = f_alice + 1.0 * 1.0 * logits[:, 0]
    dt = (time.perf_counter() - t0) / args.steps
    print(f"arch={args.arch} batch={args.batch} cache={args.cache_len} "
          f"steps={args.steps}")
    print(f"decode latency (CPU smoke config): {dt * 1e3:.2f} ms/token")
    print(f"assembled prediction shape: {f_alice.shape}, "
          f"finite: {bool(jnp.all(jnp.isfinite(f_alice)))}")


if __name__ == "__main__":
    main()
