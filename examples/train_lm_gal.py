"""End-to-end driver: GAL over two transformer organizations on a token LM
task — the paper's protocol applied to the assigned-architecture substrate.

Two orgs hold vertically-split token views (vocab factorization: org 0 sees
the high bits, org 1 the low bits); Alice holds next-token labels. Per
assistance round each org runs `--local-steps` AdamW steps of its transformer
on the broadcast pseudo-residual, then Alice fits assistance weights and
line-searches eta.

Defaults are CPU-sized (a few minutes). `--preset 100m` trains ~100M-param
orgs for a few hundred local steps — the production-scale configuration for
a real accelerator host.

Run: PYTHONPATH=src python examples/train_lm_gal.py [--preset 100m]
"""
import argparse
from dataclasses import replace

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import gal_lm
from repro.data.tokens import make_token_stream, token_batches


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=("smoke", "100m"), default="smoke")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--local-steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    args = ap.parse_args()

    base = get_arch("llama3-8b", smoke=True)
    if args.preset == "100m":
        cfg = replace(base, n_layers=12, d_model=768, n_heads=12,
                      n_kv_heads=4, d_ff=2048, vocab=8192)
        local_steps = args.local_steps or 200
        batch, seq = args.batch or 16, args.seq or 256
    else:
        cfg = replace(base, vocab=1024)
        local_steps = args.local_steps or 10
        batch, seq = args.batch or 4, args.seq or 64

    n_params = 0
    rng_np = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    stream = make_token_stream(rng_np, cfg.vocab, 200_000)
    toks, labels = next(token_batches(stream, batch, seq, rng_np))
    toks, labels = jnp.asarray(toks), jnp.asarray(labels)

    import math
    root = int(math.isqrt(cfg.vocab))
    orgs = [
        gal_lm.LMOrganization(0, cfg, lambda t: (t // root) % cfg.vocab),
        gal_lm.LMOrganization(1, cfg, lambda t: (t % root) % cfg.vocab),
    ]
    for i, org in enumerate(orgs):
        org.init(jax.random.fold_in(key, i), lr=3e-3)
        n_params = sum(x.size for x in jax.tree_util.tree_leaves(org.params))
    print(f"arch={cfg.arch} per-org params={n_params:,} "
          f"batch={batch} seq={seq} rounds={args.rounds} "
          f"local_steps={local_steps}")

    res = gal_lm.fit_lm(key, orgs, toks, labels, rounds=args.rounds,
                        local_steps=local_steps)
    for t, xent in enumerate(res.history["train_xent"]):
        eta = f" eta={res.etas[t-1]:.2f}" if t else ""
        print(f" round {t}: train xent={xent:.4f}{eta}")
    drop = res.history["train_xent"][0] - res.history["train_xent"][-1]
    print(f"xent improvement over {args.rounds} assistance rounds: {drop:.4f}")
    assert drop > 0, "GAL rounds must decrease the overarching loss"


if __name__ == "__main__":
    main()
