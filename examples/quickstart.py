"""Quickstart: 4 organizations collaborate on a regression task via GAL.

Nobody shares data, models, or objective functions: org 0 (Alice) holds the
labels; orgs hold disjoint vertical feature slices and *different* private
model classes (the paper's model autonomy).

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

from repro.core import boosting, gal
from repro.core.gal import GALConfig
from repro.core.losses import get_loss
from repro.core.organizations import make_orgs
from repro.data.partition import split_features
from repro.data.synthetic import make_regression, train_test_split
from repro.metrics.metrics import mad
from repro.models.zoo import KernelRidge, Linear, MLP, StumpBoost


def main():
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)

    ds = make_regression(rng, n=440, d=12)
    train, test = train_test_split(ds, rng)
    xs = split_features(train.x, 4)         # vertical split across 4 orgs
    xs_te = split_features(test.x, 4)
    loss = get_loss("mse")                   # Alice's overarching L1

    # model autonomy: every org picks its own private model class
    models = [Linear(), StumpBoost(n_stumps=40), KernelRidge(), MLP((32,))]
    orgs = make_orgs(xs, models)

    print("== GAL: 6 assistance rounds ==")
    result = gal.fit(key, orgs, train.y, loss, GALConfig(rounds=6),
                     eval_sets={"test": (xs_te, test.y)}, metric_fn=mad)
    for t, (eta, w) in enumerate(zip(result.etas, result.weights)):
        w_str = "[" + " ".join(f"{v:.2f}" for v in np.asarray(w)) + "]"
        print(f" round {t}: eta={eta:5.2f}  weights={w_str}  "
              f"test MAD={result.history['test_metric'][t + 1]:.3f}")

    alone = boosting.fit_alone(
        key, xs[0], train.y, loss, Linear(), GALConfig(rounds=6),
        eval_sets={"test": ([xs_te[0]], test.y)}, metric_fn=mad)
    joint = boosting.fit_joint(
        key, xs, train.y, loss, Linear(), GALConfig(rounds=6),
        eval_sets={"test": (xs_te, test.y)}, metric_fn=mad)

    print("\n== final test MAD ==")
    print(f" Alone (org 0 only) : {alone.history['test_metric'][-1]:.3f}")
    print(f" GAL (decentralized): {result.history['test_metric'][-1]:.3f}")
    print(f" Joint (oracle)     : {joint.history['test_metric'][-1]:.3f}")

    # prediction-stage API (paper Alg. 1, Prediction Stage)
    preds = result.predict(xs_te)
    print(f" predict() MAD      : {float(mad(test.y, preds)):.3f}")


if __name__ == "__main__":
    main()
