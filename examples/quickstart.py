"""Quickstart: the full GAL lifecycle on 4 collaborating organizations.

Nobody shares data, models, or objective functions: org 0 (Alice) holds the
labels; orgs hold disjoint vertical feature slices and *different* private
model classes (the paper's model autonomy). The walk-through covers the
whole production lifecycle:

  fit (6 rounds) -> save artifact -> load in a "fresh process" -> serve
  -> resume the collaboration to 10 rounds without refitting rounds 0-5

Run: PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import numpy as np
import jax

from repro.checkpoint import load_artifact, save_artifact
from repro.core import boosting, gal
from repro.core.gal import GALConfig
from repro.core.losses import get_loss
from repro.core.organizations import make_orgs
from repro.data.partition import split_features
from repro.data.synthetic import make_regression, train_test_split
from repro.metrics.metrics import mad
from repro.models.zoo import KernelRidge, Linear, MLP, StumpBoost


def main():
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)

    ds = make_regression(rng, n=440, d=12)
    train, test = train_test_split(ds, rng)
    xs = split_features(train.x, 4)         # vertical split across 4 orgs
    xs_te = split_features(test.x, 4)
    loss = get_loss("mse")                   # Alice's overarching L1

    # model autonomy: every org picks its own private model class; the org
    # execution planner fuses the whole mix into one compiled round loop
    models = [Linear(), StumpBoost(n_stumps=40), KernelRidge(), MLP((32,))]
    make = lambda: make_orgs(xs, models)                        # noqa: E731

    print("== GAL: 6 assistance rounds ==")
    result = gal.fit(key, make(), train.y, loss, GALConfig(rounds=6),
                     eval_sets={"test": (xs_te, test.y)}, metrics=("mad",))
    for t, (eta, w) in enumerate(zip(result.etas, result.weights)):
        w_str = "[" + " ".join(f"{v:.2f}" for v in np.asarray(w)) + "]"
        print(f" round {t}: eta={eta:5.2f}  weights={w_str}  "
              f"test MAD={result.history['test_mad'][t + 1]:.3f}")

    alone = boosting.fit_alone(
        key, xs[0], train.y, loss, Linear(), GALConfig(rounds=6),
        eval_sets={"test": ([xs_te[0]], test.y)}, metric_fn=mad)
    joint = boosting.fit_joint(
        key, xs, train.y, loss, Linear(), GALConfig(rounds=6),
        eval_sets={"test": (xs_te, test.y)}, metric_fn=mad)

    print("\n== final test MAD ==")
    print(f" Alone (org 0 only) : {alone.history['test_metric'][-1]:.3f}")
    print(f" GAL (decentralized): {result.history['test_mad'][-1]:.3f}")
    print(f" Joint (oracle)     : {joint.history['test_metric'][-1]:.3f}")

    with tempfile.TemporaryDirectory() as tmp:
        # fit once ... the artifact captures the plan, stacked round
        # params, etas/weights, history, and the round-scan resume carry
        path = save_artifact(result, tmp + "/gal-demo")
        print(f"\n== artifact saved ({result.engine} engine) ==")

        # ... serve forever: a fresh process loads and predicts with NO
        # refit and NO Organization objects — bitwise-identical outputs
        art = load_artifact(path)
        preds_mem = result.predict(xs_te)
        preds_art = art.predict(xs_te)
        print(f" loaded predict MAD : "
              f"{float(mad(test.y, preds_art)):.3f} "
              f"(bitwise == in-memory: "
              f"{bool(np.array_equal(np.asarray(preds_mem), np.asarray(preds_art)))})")

        # ... and resume: extend the collaboration to 10 rounds — rounds
        # 0-5 are NOT refit, and the curve is draw-for-draw what a
        # one-shot 10-round fit would produce
        result10 = gal.fit(key, make(), train.y, loss,
                           GALConfig(rounds=10),
                           eval_sets={"test": (xs_te, test.y)},
                           metrics=("mad",), resume_from=path)
        print(f" resumed 6 -> {result10.rounds} rounds: "
              f"test MAD {result.history['test_mad'][-1]:.3f} -> "
              f"{result10.history['test_mad'][-1]:.3f}")

    # prediction-stage API (paper Alg. 1, Prediction Stage)
    preds = result10.predict(xs_te)
    print(f" predict() MAD      : {float(mad(test.y, preds)):.3f}")


if __name__ == "__main__":
    main()
