"""Image-patch collaboration (paper Sec. 4.2): 8 organizations each hold one
patch of every image; the CENTRAL patches carry the signal, and the gradient
assistance weights discover that (paper Fig. 4c interpretability claim).

Also demonstrates Deep Model Sharing (one extractor + per-round heads) and
round-resumable checkpointing.

Run: PYTHONPATH=src python examples/multi_org_images.py
"""
import tempfile

import numpy as np
import jax

from repro.checkpoint import GALCheckpoint
from repro.core import gal
from repro.core.gal import GALConfig
from repro.core.losses import get_loss
from repro.core.organizations import make_orgs
from repro.data.partition import flatten_for_tabular, split_image_patches
from repro.data.synthetic import make_patch_images, train_test_split
from repro.metrics.metrics import accuracy
from repro.models.zoo import ConvNet


def main():
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    ds = make_patch_images(rng, n=256, size=8, k=4, informative_center=True)
    train, test = train_test_split(ds, rng)
    xs = split_image_patches(train.x, 8)       # 2x4 grid; centre = {1,2,5,6}
    xs_te = split_image_patches(test.x, 8)

    model = ConvNet(widths=(8, 16), epochs=30)
    orgs = make_orgs(xs, model, dms=True)      # Deep Model Sharing
    loss = get_loss("xent")
    res = gal.fit(key, orgs, train.y, loss, GALConfig(rounds=3),
                  eval_sets={"test": (xs_te, test.y)}, metric_fn=accuracy)

    print("per-round test accuracy:",
          [f"{v:.1f}" for v in res.history["test_metric"]])
    w0 = np.asarray(res.weights[0])
    print("round-0 assistance weights (orgs 1..8):",
          [f"{v:.2f}" for v in w0])
    centre, border = w0[[1, 2, 5, 6]].sum(), w0[[0, 3, 4, 7]].sum()
    print(f"centre patches weight share: {centre:.2f} "
          f"(border: {border:.2f}) -> interpretable: {centre > border}")
    print(f"DMS: per-org extractors=1, heads={orgs[0].n_rounds_fit} "
          f"(T x memory saving vs per-round models)")

    # checkpoint the collaboration per round
    with tempfile.TemporaryDirectory() as d:
        ck = GALCheckpoint(d)
        for t, (eta, w) in enumerate(zip(res.etas, res.weights)):
            ck.save_round(t, eta, w, [None] * len(orgs))
        print(f"checkpointed rounds: 0..{ck.latest_round()} "
              f"(resume via GALCheckpoint.latest_round)")


if __name__ == "__main__":
    main()
