"""One benchmark per paper table/figure, on synthetic stand-ins (DESIGN.md
Sec. 1). Each function prints CSV rows ``table,setting,metric,value`` plus the
paper's qualitative check (PASS/FAIL)."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import al, boosting, gal
from repro.core.gal import GALConfig
from repro.core.losses import get_loss, lq_loss
from repro.core.organizations import make_orgs
from repro.core.protocol_sim import complexity_table
from repro.data.partition import (
    flatten_for_tabular, split_channels, split_features, split_image_patches,
)
from repro.data.synthetic import (
    make_blobs, make_classification, make_multimodal_series,
    make_patch_images, make_regression, train_test_split,
)
from repro.metrics.metrics import accuracy, auroc, mad
from repro.models.zoo import ConvNet, GRUNet, KernelRidge, Linear, MLP, StumpBoost

KEY = jax.random.PRNGKey(0)
CFG = GALConfig(rounds=6)


def _row(table, setting, metric, value, check=""):
    print(f"{table},{setting},{metric},{value:.4g},{check}", flush=True)


def _tabular(seed=0, n=420, d=12, m=4):
    rng = np.random.default_rng(seed)
    ds = make_regression(rng, n=n, d=d)
    tr, te = train_test_split(ds, rng)
    return (split_features(tr.x, m), tr.y,
            split_features(te.x, m), te.y)


def table1_model_autonomy() -> bool:
    """Paper Table 1: Linear / GB / KernelRidge(SVM) / mixed local models;
    checks GAL ~ Joint >> Alone for each."""
    xs, y, xs_te, y_te = _tabular()
    loss = get_loss("mse")
    ok = True
    joint = boosting.fit_joint(KEY, xs, y, loss, Linear(), CFG,
                               eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
    alone = boosting.fit_alone(KEY, xs[0], y, loss, Linear(), CFG,
                               eval_sets={"test": ([xs_te[0]], y_te)},
                               metric_fn=mad)
    j, a = joint.history["test_metric"][-1], alone.history["test_metric"][-1]
    _row("table1", "Joint-Linear", "MAD", j)
    _row("table1", "Alone-Linear", "MAD", a)
    models = {
        "GAL-Linear": Linear(),
        "GAL-GB": StumpBoost(n_stumps=40),
        "GAL-KRR(SVM)": KernelRidge(),
        "GAL-GB-KRR-mix": [StumpBoost(n_stumps=40), KernelRidge(),
                           StumpBoost(n_stumps=40), KernelRidge()],
    }
    for name, model in models.items():
        res = gal.fit(KEY, make_orgs(xs, model), y, loss, CFG,
                      eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
        g = res.history["test_metric"][-1]
        good = g < a * 0.8
        ok &= good
        _row("table1", name, "MAD", g, "PASS" if good else "FAIL")
    return ok


def table2_deep_model_sharing() -> bool:
    """Paper Table 2 + Sec 4.2: CNN patch orgs; GAL >> Alone; DMS between."""
    rng = np.random.default_rng(1)
    ds = make_patch_images(rng, n=256, size=8, k=4)
    tr, te = train_test_split(ds, rng)
    xs, xs_te = split_image_patches(tr.x, 4), split_image_patches(te.x, 4)
    loss = get_loss("xent")
    model = ConvNet(widths=(8, 16), epochs=40)
    cfg = GALConfig(rounds=4)
    res = gal.fit(KEY, make_orgs(xs, model), tr.y, loss, cfg,
                  eval_sets={"test": (xs_te, te.y)}, metric_fn=accuracy)
    dms = gal.fit(KEY, make_orgs(xs, model, dms=True), tr.y, loss, cfg,
                  eval_sets={"test": (xs_te, te.y)}, metric_fn=accuracy)
    alone = boosting.fit_alone(
        KEY, xs[0], tr.y, loss, model, cfg,
        eval_sets={"test": ([xs_te[0]], te.y)}, metric_fn=accuracy)
    g = res.history["test_metric"][-1]
    d_ = dms.history["test_metric"][-1]
    a = alone.history["test_metric"][-1]
    _row("table2", "GAL-CNN", "acc", g)
    _row("table2", "GAL_DMS-CNN", "acc", d_)
    _row("table2", "Alone-CNN", "acc", a)
    ok = g > a and d_ > a
    _row("table2", "GAL,DMS>Alone", "bool", float(ok),
         "PASS" if ok else "FAIL")
    return ok


def table3_case_study_timeseries() -> bool:
    """Paper Table 3 (MIMIC-like): 4 modality orgs with GRU local models,
    regression (MIMICL) + imbalanced binary (MIMICM)."""
    rng = np.random.default_rng(2)
    ok = True
    for task, metric, better in (("regression", mad, "lower"),
                                 ("binary", auroc, "higher")):
        ds = make_multimodal_series(rng, n=384, t=8, task=task)
        tr, te = train_test_split(ds, rng)
        dims = (6, 4, 8, 4)
        xs, xs_te = split_channels(tr.x, dims), split_channels(te.x, dims)
        loss = get_loss("mse" if task == "regression" else "bce")
        model = GRUNet(hidden_size=16, epochs=60)
        cfg = GALConfig(rounds=3)
        res = gal.fit(KEY, make_orgs(xs, model), tr.y, loss, cfg,
                      eval_sets={"test": (xs_te, te.y)}, metric_fn=metric)
        alone = boosting.fit_alone(
            KEY, xs[1], tr.y, loss, model, cfg,
            eval_sets={"test": ([xs_te[1]], te.y)}, metric_fn=metric)
        g = res.history["test_metric"][-1]
        a = alone.history["test_metric"][-1]
        good = g < a if better == "lower" else g > a
        ok &= good
        name = "MIMICL-like" if task == "regression" else "MIMICM-like"
        _row("table3", f"GAL-{name}", metric.__name__, g)
        _row("table3", f"Alone-{name}", metric.__name__, a,
             "PASS" if good else "FAIL")
    return ok


def table4_local_loss_ablation() -> bool:
    """Paper Table 4: ell_q local losses; classification favors q > 1."""
    rng = np.random.default_rng(3)
    ds = make_classification(rng, n=500, d=16, k=2)
    tr, te = train_test_split(ds, rng)
    xs, xs_te = split_features(tr.x, 4), split_features(te.x, 4)
    loss = get_loss("xent")
    accs = {}
    for q in (1.0, 1.5, 2.0, 4.0):
        res = gal.fit(KEY, make_orgs(xs, MLP((16,), epochs=80),
                                     local_losses=lq_loss(q)),
                      tr.y, loss, GALConfig(rounds=3),
                      eval_sets={"test": (xs_te, te.y)}, metric_fn=accuracy)
        accs[q] = res.history["test_metric"][-1]
        _row("table4", f"l{q:g}", "acc", accs[q])
    ok = max(accs[1.5], accs[2.0], accs[4.0]) >= accs[1.0] - 1.0
    _row("table4", "q>1 competitive", "bool", float(ok),
         "PASS" if ok else "FAIL")
    return ok


def table5_privacy() -> bool:
    """Paper Table 5: GAL_DP / GAL_IP still beat Alone."""
    xs, y, xs_te, y_te = _tabular(seed=4)
    loss = get_loss("mse")
    alone = boosting.fit_alone(KEY, xs[0], y, loss, Linear(), CFG,
                               eval_sets={"test": ([xs_te[0]], y_te)},
                               metric_fn=mad)
    a = alone.history["test_metric"][-1]
    _row("table5", "Alone", "MAD", a)
    ok = True
    for mech in ("dp", "ip"):
        res = gal.fit(KEY, make_orgs(xs, Linear()), y, loss,
                      GALConfig(rounds=6, privacy=mech),
                      eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
        g = res.history["test_metric"][-1]
        good = g < a
        ok &= good
        _row("table5", f"GAL_{mech.upper()}", "MAD", g,
             "PASS" if good else "FAIL")
    return ok


def table6_noise_robust_weights() -> bool:
    """Paper Table 6 + Fig 5: assistance weights beat direct average when
    half the orgs are noisy (sigma in {1, 5})."""
    xs, y, xs_te, y_te = _tabular(seed=5)
    loss = get_loss("mse")
    ok = True
    for sigma in (1.0, 5.0):
        sigmas = [0.0, sigma, 0.0, sigma]
        w = gal.fit(KEY, make_orgs(xs, Linear(), noise_sigmas=sigmas), y,
                    loss, GALConfig(rounds=4, use_weights=True),
                    eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
        avg = gal.fit(KEY, make_orgs(xs, Linear(), noise_sigmas=sigmas), y,
                      loss, GALConfig(rounds=4, use_weights=False),
                      eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
        gw = w.history["test_metric"][-1]
        ga = avg.history["test_metric"][-1]
        good = gw < ga
        ok &= good
        _row("table6", f"weights-sigma{sigma:g}", "MAD", gw)
        _row("table6", f"average-sigma{sigma:g}", "MAD", ga,
             "PASS" if good else "FAIL")
    return ok


def fig4_convergence_and_interpretability() -> bool:
    """Fig 4: (a) GAL ~ centralized in < 10 rounds and beats AL at equal
    budget; (b) line-searched eta >> constant; (c) central patches earn
    larger weights."""
    xs, y, xs_te, y_te = _tabular(seed=6)
    loss = get_loss("mse")
    res = gal.fit(KEY, make_orgs(xs, Linear()), y, loss, GALConfig(rounds=10),
                  eval_sets={"test": (xs_te, y_te)}, metric_fn=mad)
    joint = boosting.fit_joint(KEY, xs, y, loss, Linear(), GALConfig(rounds=10),
                               eval_sets={"test": (xs_te, y_te)},
                               metric_fn=mad)
    within = res.history["test_metric"][-1] < \
        joint.history["test_metric"][-1] * 1.5
    _row("fig4a", "rounds_to_near_oracle", "rounds",
         float(next((i for i, v in enumerate(res.history["test_metric"])
                     if v < joint.history["test_metric"][-1] * 1.5), 10)),
         "PASS" if within else "FAIL")

    const = gal.fit(KEY, make_orgs(xs, Linear()), y, loss,
                    GALConfig(rounds=4, eta_method="constant"))
    ls = gal.fit(KEY, make_orgs(xs, Linear()), y, loss,
                 GALConfig(rounds=4, eta_method="lbfgs"))
    faster = ls.history["train_loss"][-1] <= const.history["train_loss"][-1]
    _row("fig4b", "linesearch<=const", "loss",
         ls.history["train_loss"][-1], "PASS" if faster else "FAIL")

    rng = np.random.default_rng(7)
    ds = make_patch_images(rng, n=160, size=8, k=4)
    patches = flatten_for_tabular(split_image_patches(ds.x, 8))
    pres = gal.fit(KEY, make_orgs(patches, Linear()), ds.y, get_loss("xent"),
                   GALConfig(rounds=2))
    w0 = np.asarray(pres.weights[0])
    centre = float(w0[[1, 2, 5, 6]].sum())
    border = float(w0[[0, 3, 4, 7]].sum())
    interp = centre > border
    _row("fig4c", "centre_weight_share", "w", centre,
         "PASS" if interp else "FAIL")
    return within and faster and interp


def table14_complexity() -> bool:
    """Paper Table 14: AL = Mx GAL in rounds/time; DMS = 1x space."""
    rows = complexity_table(n=60000, k=10, m=8, rounds=10)
    ok = True
    for r in rows:
        _row("table14", r["method"], "comm_rounds_x",
             r["communication_rounds_x"])
        _row("table14", r["method"], "comp_time_x", r["computation_time_x"])
        _row("table14", r["method"], "comp_space_x", r["computation_space_x"])
    al_r = [r for r in rows if r["method"] == "AL"][0]
    gal_r = [r for r in rows if r["method"] == "GAL"][0]
    dms_r = [r for r in rows if r["method"] == "GAL_DMS"][0]
    ok = (al_r["communication_rounds_x"] == 8.0
          and gal_r["communication_rounds_x"] == 1.0
          and dms_r["computation_space_x"] == 1.0)
    _row("table14", "relations", "bool", float(ok), "PASS" if ok else "FAIL")
    return ok


ALL_TABLES = {
    "table1": table1_model_autonomy,
    "table2": table2_deep_model_sharing,
    "table3": table3_case_study_timeseries,
    "table4": table4_local_loss_ablation,
    "table5": table5_privacy,
    "table6": table6_noise_robust_weights,
    "fig4": fig4_convergence_and_interpretability,
    "table14": table14_complexity,
}
