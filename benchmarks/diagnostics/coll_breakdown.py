"""Diagnostic: per-shape collective-byte breakdown (loop-aware) for one
(arch x shape) combo — the tool behind the SS Perf root-cause rows.

Usage:
  PYTHONPATH=src python benchmarks/diagnostics/coll_breakdown.py \
      llama3-8b decode_32k [loss_kind]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re, sys
from collections import Counter
from dataclasses import replace
import jax
from repro.configs import SHAPES, get_arch
from repro.launch.mesh import make_device_mesh, production_mesh_spec
from repro.launch import sharding as shd
from repro.launch.specs import abstract_params, config_for_shape, train_batch_specs, serve_specs
from repro.train.steps import make_train_step, make_serve_step
from repro.models import pspec as act_hints
from repro.roofline import hlo_stats

arch, shape_name, kind = sys.argv[1], sys.argv[2], (sys.argv[3] if len(sys.argv)>3 else "gal_residual_topk")
shape = SHAPES[shape_name]
cfg = config_for_shape(get_arch(arch), shape)
if shape.kind == "train":
    cfg = replace(cfg, remat=True, attn_chunk=1024)
mesh = make_device_mesh(*production_mesh_spec()); act_hints.set_mesh(mesh)
aparams = abstract_params(cfg)
params_in = shd.attach(aparams, shd.params_shardings(cfg, mesh, aparams))
with mesh:
    if shape.kind == "train":
        train_step, opt = make_train_step(cfg, kind, microbatch=2)
        aopt = jax.eval_shape(opt.init, aparams)
        opt_in = shd.attach(aopt, shd.opt_state_shardings(cfg, mesh, aopt, aparams))
        bspecs = train_batch_specs(cfg, shape, kind)
        batch_in = shd.attach(bspecs, shd.batch_shardings(cfg, mesh, bspecs))
        compiled = jax.jit(train_step).lower(params_in, opt_in, batch_in).compile()
    else:
        serve_step = make_serve_step(cfg)
        token_spec, cache_spec = serve_specs(cfg, shape)
        c_sh = shd.cache_shardings(cfg, mesh, cache_spec, shape)
        t_sh = shd.token_sharding(mesh, token_spec, shape)
        compiled = jax.jit(serve_step, donate_argnums=(1,)).lower(
            params_in, shd.attach(cache_spec, c_sh), shd.attach(token_spec, t_sh)).compile()
hlo = compiled.as_text()

# per-shape collective contribution with trip multipliers
comps = hlo_stats.parse_hlo(hlo)
contrib = Counter()
def walk(name, mult):
    comp = comps.get(name)
    if comp is None: return
    for ins in comp.instructions:
        op = ins.op; rhs = ins.rhs
        if op == "while":
            body = hlo_stats._called(rhs, "body"); cond = hlo_stats._called(rhs, "condition")
            trips = hlo_stats._trip_count(rhs, comps.get(cond))
            walk(body, mult*max(trips,1)); continue
        m = re.search(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(-start)?\(", rhs)
        if m and "-done(" not in rhs:
            shape_m = hlo_stats._SHAPE_RE.search(hlo_stats._result_part(rhs))
            contrib[(m.group(1), shape_m.group(0) if shape_m else "?")] += ins.result_bytes*mult
walk("ENTRY", 1)
for (kind2, shp), b in contrib.most_common(12):
    print(f"{b/2**30:9.2f} GiB  {kind2:18s} {shp}")
