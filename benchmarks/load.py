"""Serving load benchmark: the multi-tenant batched GAL service vs the
one-request-at-a-time baseline, on the SAME saved artifacts.

Fits ``--tenants`` small MLP collaborations (distinct seeds), saves each
as a ``gal-artifact/v1`` directory, registers the directories with an
``ArtifactRegistry`` (so the measured path is the full load-from-disk
serving path), warms every tenant's bucket cache, then measures:

  * ``run_serial`` — every request is its own blocked 1-row launch
    through the tenant's jitted bucket cache (the unbatched baseline);
  * ``run_load``  — ``--clients`` concurrent closed-loop clients, each
    keeping ``--depth`` requests in flight, served through per-tenant
    micro-batching (docs/serving.md).

The MLP workload is deliberately weight-heavy: a 1-row launch and a
16-row launch read the same stacked round params, so packing concurrent
requests amortizes the launch almost for free — the regime in which a
production Prediction Stage benefits from batching. Results land as
``gal-bench/v1`` rows ``serve_throughput`` / ``serve_p99`` in
``--json-out`` (the BENCH_PR9.json CI artifact).

Run: PYTHONPATH=src python -m benchmarks.load --json-out BENCH_PR9.json
"""
from __future__ import annotations

from repro.utils.force_devices import apply_force_devices
apply_force_devices()

import argparse
import tempfile
from pathlib import Path

import jax
import numpy as np


def fit_tenant_artifact(seed: int, out_dir: Path, *, rounds: int,
                        orgs: int, hidden: int, epochs: int,
                        d_total: int = 64, n: int = 256) -> Path:
    """Fit one tenant's collaboration (per-seed data + init) and save it
    as a versioned artifact directory; returns the directory."""
    from repro.checkpoint import save_artifact
    from repro.core import gal
    from repro.core.gal import GALConfig
    from repro.core.losses import get_loss
    from repro.core.organizations import make_orgs
    from repro.data.partition import split_features
    from repro.data.synthetic import make_regression, train_test_split
    from repro.models.zoo import MLP

    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    ds = make_regression(rng, n=n, d=d_total)
    train, _ = train_test_split(ds, rng)
    xs = split_features(train.x, orgs)
    res = gal.fit(key, make_orgs(xs, MLP(hidden=(hidden, hidden),
                                         epochs=epochs)),
                  train.y, get_loss("mse"),
                  GALConfig(rounds=rounds, engine="scan"))
    path = out_dir / f"tenant{seed}"
    save_artifact(res, path)
    return path


def build_requests(registry, tenants, total: int, clients: int,
                   rows_per_tenant: int = 64):
    """Single-row requests synthesized from each tenant's fitted
    geometry. Waves of ``clients`` consecutive requests share a tenant,
    so under the i %% clients fan-out every client hits the same tenant
    at the same time — the batcher sees full per-tenant complements."""
    tenant_rows = {}
    for ti, tenant in enumerate(tenants):
        widths = registry.get(tenant).widths
        rng = np.random.default_rng(1000 + ti)
        tenant_rows[tenant] = [
            rng.normal(size=(rows_per_tenant, w)).astype(np.float32)
            for w in widths]
    requests = []
    for i in range(total):
        tenant = tenants[(i // max(clients, 1)) % len(tenants)]
        row = i % rows_per_tenant
        requests.append(
            (tenant, [x[row:row + 1] for x in tenant_rows[tenant]]))
    return requests


def bench_serve(args) -> list:
    """Run the load benchmark; returns the gal-bench/v1 rows."""
    from repro.serve import (ArtifactRegistry, GALService, run_load,
                             run_serial)

    registry = ArtifactRegistry(max_batch=args.max_batch)
    tenants = []
    with tempfile.TemporaryDirectory(prefix="gal-serve-bench-") as tmp:
        for seed in range(args.tenants):
            path = fit_tenant_artifact(
                seed, Path(tmp), rounds=args.rounds, orgs=args.orgs,
                hidden=args.hidden, epochs=args.epochs)
            tenant = f"tenant{seed}"
            registry.register(tenant, path)
            tenants.append(tenant)
        print(f"# {len(tenants)} tenant artifacts fit + saved + registered")

        requests = build_requests(registry, tenants, args.requests,
                                  args.clients)
        service = GALService(registry,
                             deadline_s=args.deadline_ms / 1e3,
                             flush_rows=args.flush_rows)
        try:
            buckets = sum(service.warmup(t) for t in tenants)
            print(f"# warmed {buckets} bucket compilations")
            serial = run_serial(
                registry, requests[:max(args.clients, args.requests // 4)])
            load = run_load(service, requests, clients=args.clients,
                            depth=args.depth)
        finally:
            service.close()
        stats = service.stats()

    rpb = [t["rows_per_batch"] for t in stats["tenants"].values()]
    speedup = load["requests_per_sec"] / serial["requests_per_sec"]
    print(f"serve_throughput,{load['requests_per_sec']:.0f} req/s,"
          f"serial {serial['requests_per_sec']:.0f} req/s,"
          f"speedup {speedup:.2f}x,rows/batch {np.mean(rpb):.1f}")
    print(f"serve_p99,p50 {load['p50_ms']:.2f} ms,"
          f"p99 {load['p99_ms']:.2f} ms")
    common = {
        "tenants": args.tenants, "clients": args.clients,
        "depth": args.depth, "requests": load["requests"],
        "max_batch": args.max_batch, "flush_rows": args.flush_rows,
        "deadline_ms": args.deadline_ms,
        "model": f"mlp{args.hidden}", "rounds": args.rounds,
        "orgs": args.orgs,
    }
    return [
        {"scenario": "serve_throughput", **common,
         "seconds": load["seconds"],
         "requests_per_sec": load["requests_per_sec"],
         "serial_requests_per_sec": serial["requests_per_sec"],
         "speedup_vs_serial": speedup,
         "rows_per_batch": float(np.mean(rpb))},
        {"scenario": "serve_p99", **common,
         "seconds": load["seconds"],
         "p50_ms": load["p50_ms"], "p99_ms": load["p99_ms"],
         "mean_ms": load["mean_ms"],
         "serial_p50_ms": serial["p50_ms"],
         "serial_p99_ms": serial["p99_ms"]},
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--requests", type=int, default=1600)
    ap.add_argument("--depth", type=int, default=4,
                    help="requests each client keeps in flight")
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--flush-rows", type=int, default=16)
    ap.add_argument("--deadline-ms", type=float, default=10.0)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--orgs", type=int, default=4)
    ap.add_argument("--hidden", type=int, default=256,
                    help="per-org MLP hidden width (weight traffic per "
                         "launch — what batching amortizes)")
    ap.add_argument("--epochs", type=int, default=5,
                    help="local fit epochs (serving bench: quality is "
                         "irrelevant, keep the fit cheap)")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="write the gal-bench/v1 artifact here")
    args = ap.parse_args()
    if args.tenants < 1:
        ap.error("--tenants must be >= 1")

    rows = bench_serve(args)
    if args.json_out:
        from benchmarks.run import write_bench_json
        write_bench_json(args.json_out, rows)


if __name__ == "__main__":
    main()
