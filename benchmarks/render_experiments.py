"""Render the SS Dry-run and SS Roofline tables of EXPERIMENTS.md from the
dry-run JSON artifacts. Usage:
  PYTHONPATH=src python benchmarks/render_experiments.py > /tmp/tables.md
"""
import json
from pathlib import Path

ART = Path("benchmarks/results/dryrun")


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def main():
    recs = [json.loads(f.read_text()) for f in sorted(ART.glob("*.json"))]
    pods = {"16x16": [r for r in recs if r["mesh"] == "16x16"],
            "2x16x16": [r for r in recs if r["mesh"] == "2x16x16"]}

    print("### Dry-run table (memory analysis, per device)\n")
    for mesh, rows in pods.items():
        print(f"\n**mesh {mesh} ({rows[0]['n_chips'] if rows else '?'} chips)"
              f" — {len(rows)}/40 combos lowered+compiled**\n")
        print("| arch | shape | peak GiB/dev | args GiB | temps GiB |"
              " collectives (loop-aware) | compile s |")
        print("|---|---|---|---|---|---|---|")
        for r in rows:
            m = r["memory"]
            coll = r.get("collectives_loop_aware", r["collectives_raw"])
            cs = " ".join(f"{k.split('-')[-1] if False else k}:"
                          f"{fmt_bytes(v)}G" for k, v in sorted(coll.items()))
            print(f"| {r['arch']} | {r['shape']} "
                  f"| {fmt_bytes(m['peak_bytes_per_device'])} "
                  f"| {fmt_bytes(m['argument_bytes_per_device'])} "
                  f"| {fmt_bytes(m['temp_bytes_per_device'])} "
                  f"| {cs} | {r['compile_s']} |")

    print("\n### Roofline table (single-pod, per chip, seconds per step)\n")
    print("| arch | shape | t_compute | t_memory | t_collective | dominant |"
          " MODEL_FLOPS/HLO_FLOPs | what moves the dominant term |")
    print("|---|---|---|---|---|---|---|---|")
    hints = {
        ("t_memory", "train"): "less remat recompute / bf16 stash / "
                               "top-k residual transport",
        ("t_memory", "prefill"): "flash kernel (fused softmax, no score"
                                 " round-trips)",
        ("t_memory", "decode"): "larger decode batch per chip; fuse cache"
                                " update",
        ("t_collective", "train"): "overlap FSDP gathers with compute;"
                                   " reduce-scatter grads",
        ("t_collective", "decode"): "replicate KV heads instead of hd-"
                                    "sharding (trade memory)",
        ("t_compute", "train"): "already compute-bound: raise MFU via"
                                " larger per-chip batch",
    }
    for r in pods["16x16"]:
        t = r["roofline"]
        u = r.get("useful_flops_ratio")
        u = "-" if u is None else f"{u:.2f}"
        kind = ("train" if r["shape"].startswith("train") else
                "prefill" if "prefill" in r["shape"] else "decode")
        hint = hints.get((r["dominant"], kind), "-")
        print(f"| {r['arch']} | {r['shape']} | {t['t_compute']:.3f} "
              f"| {t['t_memory']:.3f} | {t['t_collective']:.3f} "
              f"| {r['dominant'].replace('t_', '')} | {u} | {hint} |")


if __name__ == "__main__":
    main()
