"""Benchmark harness: one function per paper table/figure + microbenchmarks.

CSV format: ``name,us_per_call,derived`` for timing rows; table rows are
``table,setting,metric,value,check``. Roofline numbers come from the dry-run
artifacts (benchmarks/results/dryrun) and are summarized at the end.

Run: PYTHONPATH=src python -m benchmarks.run [--only tableN]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp


def _time_call(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def micro_benchmarks() -> None:
    """Kernel + protocol micro-timings (CPU interpret mode — relative only)."""
    from repro.kernels.ops import residual_xent
    from repro.kernels import ref
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (512, 4096))
    labels = jax.random.randint(key, (512,), 0, 4096)
    t_ref = _time_call(jax.jit(ref.residual_xent_ref), logits, labels)
    print(f"residual_xent_ref_512x4096,{t_ref:.1f},jnp-oracle")
    from repro.core.weights import fit_weights
    from repro.core.losses import lq_loss
    r = jax.random.normal(key, (1024, 8))
    preds = jax.random.normal(key, (8, 1024, 8))
    t_w = _time_call(
        lambda: fit_weights(key, r, preds, lq_loss(2.0), epochs=100))
    print(f"assistance_weights_fit_M8,{t_w:.1f},adam-100-epochs")
    from repro.optim.lbfgs import line_search
    t_ls = _time_call(
        lambda: line_search(lambda e: jnp.mean((e - 1.7) ** 2), "lbfgs"))
    print(f"eta_line_search_lbfgs,{t_ls:.1f},scalar")


def _bench_smooth_l1(r, f):
    """A custom (non-ell_q) local loss: exercises the autodiff-residual
    compile path in the engine benchmark's mixed scenario."""
    import jax.numpy as jnp
    return jnp.mean(jnp.sqrt(1.0 + jnp.square(r - f)) - 1.0)


def gal_engine_benchmark(rounds: int = 16, m: int = 4, n: int = 512,
                         d: int = 16, json_rows: list | None = None) -> None:
    """rounds/sec of gal.fit per engine and scenario — homogeneous Linear,
    the paper's GB–SVM-style mixed-model set (model autonomy, fused by the
    org execution planner), noisy orgs (Table 6), Deep Model Sharing
    (Sec. 5: the python loop retraces its growing residual stack every
    round; the grouped engine compiles the stacked-head carry ONCE), and
    the DMS + custom-loss mix — plus the stacked-round prediction stage vs
    the per-(round, org) loop. Timings include compilation — one fit call
    is the real unit of work. Rows are appended to ``json_rows`` for the
    BENCH_PR5.json artifact."""
    from repro.core import gal
    from repro.core.gal import GALConfig
    from repro.core.losses import get_loss, lq_loss
    from repro.core.organizations import make_orgs
    from repro.data.partition import pad_and_stack, split_features
    from repro.data.synthetic import make_regression, train_test_split
    from repro.models.zoo import KernelRidge, Linear, MLP, StumpBoost

    rng_np = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    ds = make_regression(rng_np, n=n, d=d)
    train, test = train_test_split(ds, rng_np)
    xs = split_features(train.x, m)
    xs_te = split_features(test.x, m)
    loss = get_loss("mse")

    scenarios = {
        "homogeneous": dict(models=lambda: Linear(), sigmas=None,
                            engines=("python", "scan")),
        "hetero_gb_svm_mix": dict(
            models=lambda: [StumpBoost(n_stumps=20) if i % 2 == 0
                            else KernelRidge() for i in range(m)],
            sigmas=None, engines=("python", "grouped")),
        "noisy": dict(models=lambda: Linear(),
                      sigmas=[0.0 if i % 2 == 0 else 1.0 for i in range(m)],
                      engines=("python", "grouped")),
        "dms": dict(models=lambda: MLP((16,), epochs=20), sigmas=None,
                    dms=True, engines=("python", "grouped")),
        "dms_custom_loss_mix": dict(
            models=lambda: [MLP((16,), epochs=20) if i % 2 == 0
                            else Linear(epochs=20) for i in range(m)],
            sigmas=None,
            dms=[i % 2 == 0 for i in range(m)],
            losses=[lq_loss(2.0) if i % 2 == 0 else _bench_smooth_l1
                    for i in range(m)],
            engines=("python", "grouped")),
    }
    results = {}
    for scen, spec in scenarios.items():
        for engine in spec["engines"]:
            cfg = GALConfig(rounds=rounds, engine=engine)
            orgs = make_orgs(xs, spec["models"](),
                             local_losses=spec.get("losses"),
                             dms=spec.get("dms", False),
                             noise_sigmas=spec["sigmas"])
            t0 = time.perf_counter()
            res = gal.fit(key, orgs, train.y, loss, cfg)
            dt = time.perf_counter() - t0
            results[(scen, engine)] = res
            rps = rounds / dt
            print(f"gal_fit_{scen}_{engine}_R{rounds}_M{m},"
                  f"{dt / rounds * 1e6:.1f},rounds_per_sec={rps:.2f}")
            if json_rows is not None:
                json_rows.append({
                    "scenario": scen, "engine": res.engine,
                    "forced_engine": engine, "rounds": rounds, "orgs": m,
                    "n": n, "d": d, "seconds": dt, "rounds_per_sec": rps,
                })
    for scen in ("dms", "dms_custom_loss_mix"):
        dt_py = [r for r in (json_rows or []) if r.get("scenario") == scen
                 and r.get("forced_engine") == "python"]
        dt_gr = [r for r in (json_rows or []) if r.get("scenario") == scen
                 and r.get("forced_engine") == "grouped"]
        if dt_py and dt_gr:
            x = dt_gr[-1]["rounds_per_sec"] / dt_py[-1]["rounds_per_sec"]
            print(f"# {scen}: grouped {x:.1f}x python")

    res = results[("homogeneous", "scan")]
    t_pred = _time_call(jax.jit(lambda xq: res.predict(xq)), xs_te)
    print(f"gal_predict_stacked_R{rounds}_M{m},{t_pred:.1f},one-vmap")
    res.unpack_to_orgs()
    xe_stack, _ = pad_and_stack(xs_te, pad_to=res.pad_to)
    t_leg = _time_call(lambda: res.predict_legacy(list(xe_stack)))
    print(f"gal_predict_legacy_R{rounds}_M{m},{t_leg:.1f},per-round-org-loop")
    if json_rows is not None:
        json_rows.append({"scenario": "predict_stacked", "engine": "scan",
                          "rounds": rounds, "orgs": m,
                          "us_per_call": t_pred})
        json_rows.append({"scenario": "predict_legacy", "engine": "python",
                          "rounds": rounds, "orgs": m,
                          "us_per_call": t_leg})


def gal_artifact_benchmark(rounds: int = 8, m: int = 4, n: int = 512,
                           d: int = 16,
                           json_rows: list | None = None) -> None:
    """The fit-once/serve-forever gap: cold start (fit the ensemble, save
    the artifact) vs warm start (load the artifact, compile the predict
    path) vs steady-state request latency on the loaded artifact. The
    warm row is what a production restart pays INSTEAD of the cold fit —
    the artifact lifecycle's whole value proposition, tracked per PR in
    the BENCH_PR5.json CI artifact."""
    import tempfile

    from repro.checkpoint import load_artifact, save_artifact
    from repro.core import gal
    from repro.core.gal import GALConfig
    from repro.core.losses import get_loss
    from repro.core.organizations import make_orgs
    from repro.data.partition import split_features
    from repro.data.synthetic import make_regression, train_test_split
    from repro.models.zoo import Linear

    rng_np = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    ds = make_regression(rng_np, n=n, d=d)
    train, test = train_test_split(ds, rng_np)
    xs = split_features(train.x, m)
    xs_te = split_features(test.x, m)

    with tempfile.TemporaryDirectory() as tmp:
        t0 = time.perf_counter()
        res = gal.fit(key, make_orgs(xs, Linear()), train.y,
                      get_loss("mse"), GALConfig(rounds=rounds))
        save_artifact(res, tmp)
        dt_cold = time.perf_counter() - t0
        print(f"gal_serve_cold_fit_R{rounds}_M{m},{dt_cold * 1e6:.1f},"
              f"fit+save_s={dt_cold:.2f}")

        t0 = time.perf_counter()
        art = load_artifact(tmp)
        serve = jax.jit(lambda xq: art.predict(xq))
        jax.block_until_ready(serve(xs_te))          # compile = warm-up
        dt_warm = time.perf_counter() - t0
        print(f"gal_serve_warm_load_R{rounds}_M{m},{dt_warm * 1e6:.1f},"
              f"load+compile_s={dt_warm:.2f};"
              f"cold_over_warm={dt_cold / max(dt_warm, 1e-9):.1f}x")

        t_req = _time_call(serve, xs_te)
        print(f"gal_serve_artifact_request_R{rounds}_M{m},{t_req:.1f},"
              f"jitted-predict-cached")
    if json_rows is not None:
        json_rows.append({"scenario": "serve_cold_fit", "engine": res.engine,
                          "rounds": rounds, "orgs": m, "seconds": dt_cold})
        json_rows.append({"scenario": "serve_warm_load", "engine": art.engine,
                          "rounds": rounds, "orgs": m, "seconds": dt_warm,
                          "cold_over_warm": dt_cold / max(dt_warm, 1e-9)})
        json_rows.append({"scenario": "serve_artifact_request",
                          "engine": art.engine, "rounds": rounds, "orgs": m,
                          "us_per_call": t_req})


def gal_membership_benchmark(rounds: int = 8, m: int = 4, n: int = 512,
                             d: int = 16,
                             json_rows: list | None = None) -> None:
    """Dynamic-membership cost rows for the BENCH artifact:

    * ``dropout_round_overhead`` — steady-state (post-compile) fit time
      with a dropout schedule vs the unmasked fit. Membership rides the
      scan inputs as a boolean row, so the masked program should cost
      within a few percent of the unmasked one; the ratio is recorded as
      DATA (CI tracks drift, the 5%% expectation is advisory here).
    * ``contrib_loo_refit`` — one leave-one-out counterfactual via resume
      from the round-``t0`` carry vs the same counterfactual fit from
      scratch: the speedup the contributivity estimators
      (``repro.core.contrib``) bank on."""
    from repro.core import gal
    from repro.core.gal import GALConfig
    from repro.core.losses import get_loss
    from repro.core.organizations import make_orgs
    from repro.data.partition import split_features
    from repro.data.synthetic import make_regression, train_test_split
    from repro.models.zoo import Linear

    rng_np = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    ds = make_regression(rng_np, n=n, d=d)
    train, _ = train_test_split(ds, rng_np)
    xs = split_features(train.x, m)
    loss = get_loss("mse")
    cfg = GALConfig(rounds=rounds, engine="scan")
    # the overhead row runs LONG (8x) so the scanned rounds — the thing
    # membership actually touches — are a visible fraction of the one-shot
    # fit; at toy sizes trace+compile dominates and is schedule-independent
    r_ov = 8 * rounds
    cfg_ov = GALConfig(rounds=r_ov, engine="scan")
    sched = np.ones((r_ov, m), bool)
    sched[1::2, m - 1] = False          # last org drops every other round

    def fit_once(membership=None, resume=None, config=cfg):
        return gal.fit(key, make_orgs(xs, Linear()), train.y, loss, config,
                       membership=membership, resume_from=resume)

    def best_of(fn, iters: int = 3) -> float:
        # each gal.fit call re-traces, so min-of-iters is the stable
        # number (first calls eat allocator/caching warm-up noise)
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    fit_once()                           # process warm-up
    t_plain = best_of(lambda: fit_once(config=cfg_ov))
    t_masked = best_of(lambda: fit_once(membership=sched, config=cfg_ov))
    ratio = t_masked / max(t_plain, 1e-12)
    print(f"gal_fit_dropout_overhead_R{r_ov}_M{m},"
          f"{t_masked / r_ov * 1e6:.1f},masked_over_unmasked={ratio:.3f}")
    if json_rows is not None:
        json_rows.append({
            "scenario": "dropout_round_overhead", "engine": "scan",
            "rounds": r_ov, "orgs": m, "n": n, "d": d,
            "seconds_unmasked": t_plain, "seconds_masked": t_masked,
            "masked_over_unmasked": ratio, "within_5pct": ratio <= 1.05,
        })

    # LOO counterfactual: resume from the t0 carry vs fit from scratch
    t0_cut = rounds // 2
    base = gal.fit(key, make_orgs(xs, Linear()), train.y, loss,
                   GALConfig(rounds=t0_cut, engine="scan"))
    loo_sched = np.ones((rounds, m), bool)
    loo_sched[t0_cut:, 0] = False       # org 0 leaves at the cut

    t_resume = best_of(lambda: fit_once(membership=loo_sched, resume=base))
    t_scratch = best_of(lambda: fit_once(membership=loo_sched))
    speedup = t_scratch / max(t_resume, 1e-12)
    print(f"gal_contrib_loo_refit_R{rounds}_M{m},"
          f"{t_resume * 1e6:.1f},resume_speedup={speedup:.2f}x"
          f";rounds_executed={rounds - t0_cut}_vs_{rounds}")
    if json_rows is not None:
        json_rows.append({
            "scenario": "contrib_loo_refit", "engine": "scan",
            "rounds": rounds, "orgs": m, "t0": t0_cut,
            "rounds_executed_resume": rounds - t0_cut,
            "seconds_resume": t_resume, "seconds_scratch": t_scratch,
            "resume_speedup": speedup,
        })


_SHARD_CELL_SNIPPET = r"""
import json, time
from repro.utils.force_devices import apply_force_devices
apply_force_devices()
import numpy as np
import jax
from repro.core import gal
from repro.core.gal import GALConfig
from repro.core.losses import get_loss
from repro.core.organizations import make_orgs
from repro.data.partition import split_features
from repro.data.synthetic import make_regression, train_test_split
from repro.models.zoo import Linear

rounds, m, n, d = {rounds}, {m}, {n}, {d}
rng_np = np.random.default_rng(0)
key = jax.random.PRNGKey(0)
ds = make_regression(rng_np, n=int(n / 0.8) + 2, d=d)
train, _ = train_test_split(ds, rng_np)          # train split has n rows
xs = split_features(train.x, m)
t0 = time.perf_counter()
res = gal.fit(key, make_orgs(xs, Linear()), train.y, get_loss("mse"),
              GALConfig(rounds=rounds, engine="{engine}",
                        residual_dtype="{dtype}"))
dt = time.perf_counter() - t0
print("CELL:" + json.dumps({{
    "engine": res.engine, "devices": len(jax.devices()), "seconds": dt,
    "n": int(train.y.shape[0]),
    "bcast": sum(res.history["comm_broadcast_bytes"]),
    "gather": sum(res.history["comm_gather_bytes"]),
}}))
"""


def _run_shard_cell(n_dev: int, m: int, n: int, d: int, rounds: int,
                    engine: str, dtype: str, timeout: int = 900):
    """One cold subprocess fit (forced device count must be set before jax
    initializes, so every cell is its own process). Returns the CELL dict
    or an error string."""
    import os
    import subprocess
    import sys

    snippet = _SHARD_CELL_SNIPPET.format(rounds=rounds, m=m, n=n, d=d,
                                         engine=engine, dtype=dtype)
    env = {**os.environ, "REPRO_FORCE_DEVICES": str(n_dev)}
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    try:
        proc = subprocess.run([sys.executable, "-c", snippet], env=env,
                              capture_output=True, text=True, timeout=timeout)
    except subprocess.TimeoutExpired:
        return f"timeout>{timeout}s"
    if proc.returncode != 0:
        return " ".join(proc.stderr.strip().splitlines()[-1:]) or "crashed"
    for line in proc.stdout.splitlines():
        if line.startswith("CELL:"):
            return json.loads(line[len("CELL:"):])
    return "no CELL line in output"


def gal_shard_scaling_benchmark(json_rows: list | None = None,
                                full: bool = False) -> None:
    """The PR8 placement grid: orgs x train rows x placement x wire dtype.

    Placements per org count M:
      * ``scan``       — the single-device baseline (vmap over orgs, D=1);
      * ``one_to_one`` — the classic org mesh, one org per device (D=M;
        skipped for M=64, where forcing 64 host devices on one machine
        times every cell against the scheduler instead of the engine);
      * ``block``      — MORE orgs than devices: D=8 forced devices carry
        M/8 orgs each (D=2 for M=4), the placement this PR adds.

    Timing is the MARGINAL round rate from a cold-process pair: each cell
    runs twice in fresh subprocesses at R and 3R rounds, and
    rounds/sec = 2R / (t_3R - t_R). Differencing two cold processes
    cancels the compile+trace time that dominates small cells; same-process
    re-timing does NOT work here (the warm second call reuses jit caches
    and the asymmetry swamps the signal). Fast cells escalate R (x4, x16)
    until the marginal clears the cold-start noise floor — a cell whose
    difference stays non-positive even then is reported failed rather
    than clamped to a fictitious rate. Comm bytes are the engine's own
    per-round ledger ints, so the bf16 rows document the halved broadcast
    next to their fp32 twins.

    The default grid is the CI smoke slice (n=512, M in {4, 16});
    ``full=True`` (the ``--full-shard-grid`` flag) runs the committed
    BENCH_PR8.json grid with n=65536 and M=64 cells — the block-vs-scan
    acceptance numbers live there."""
    grid_m = (4, 16, 64) if full else (4, 16)
    grid_n = (512, 65536) if full else (512,)
    base_r = 4
    # A cold-pair marginal below this is dominated by compile-time
    # variance between the two fresh processes, not by round cost.
    _MARGINAL_FLOOR_S = 0.4

    for n in grid_n:
        for m in grid_m:
            # wide-feature orgs at bench scale would time the local solve;
            # the big-n cells give each org one feature so the round loop
            # (broadcast, fits, weight fit, line search) is what scales
            d = 4 * m if n == 512 else m
            cells = [("scan", 1, "scan")]
            if m <= 16:
                cells.append(("one_to_one", m, "shard"))
            else:
                print(f"# skip one_to_one M={m} n={n}: would force {m} "
                      f"host devices on one machine")
            cells.append(("block", 2 if m == 4 else 8, "shard"))
            for placement, n_dev, engine in cells:
                for dtype in ("fp32", "bf16"):
                    # Fast cells put the 8-round marginal below the
                    # compile-time variance between two cold processes;
                    # escalate the round count until the difference
                    # clears the noise floor instead of clamping it.
                    for mult in (1, 4, 16):
                        r1, r3 = base_r * mult, 3 * base_r * mult
                        a = _run_shard_cell(n_dev, m, n, d, r1, engine,
                                            dtype)
                        b = _run_shard_cell(n_dev, m, n, d, r3, engine,
                                            dtype)
                        if not (isinstance(a, dict)
                                and isinstance(b, dict)):
                            break
                        marginal = b["seconds"] - a["seconds"]
                        if marginal >= _MARGINAL_FLOOR_S:
                            break
                    name = (f"gal_shard_{placement}_{dtype}_D{n_dev}"
                            f"_M{m}_N{n}")
                    if not (isinstance(a, dict) and isinstance(b, dict)):
                        print(f"{name},nan,failed={a if isinstance(a, str) else b}")
                        continue
                    if marginal <= 0:
                        print(f"{name},nan,"
                              f"failed=unstable_marginal_at_{r3}_rounds")
                        continue
                    rps = (r3 - r1) / marginal
                    print(f"{name},{marginal / (r3 - r1) * 1e6:.1f},"
                          f"rounds_per_sec={rps:.2f};engine={b['engine']};"
                          f"bcast_B_per_round={b['bcast'] // r3};"
                          f"gather_B_per_round={b['gather'] // r3}")
                    if json_rows is not None:
                        json_rows.append({
                            "scenario": "shard_scaling",
                            "placement": placement, "dtype": dtype,
                            "devices": n_dev, "engine": b["engine"],
                            "rounds": r3 - r1, "orgs": m,
                            "n": b.get("n", n), "d": d,
                            "seconds": marginal, "rounds_per_sec": rps,
                            "comm_broadcast_bytes_per_round":
                                b["bcast"] // r3,
                            "comm_gather_bytes_per_round":
                                b["gather"] // r3,
                        })


def roofline_summary(outdir: str = "benchmarks/results/dryrun") -> None:
    """Summarize the dry-run artifacts into the SS Roofline table."""
    rows = []
    for f in sorted(Path(outdir).glob("*.json")):
        r = json.loads(f.read_text())
        t = r["roofline"]
        rows.append((r["arch"], r["shape"], r["mesh"],
                     t["t_compute"], t["t_memory"], t["t_collective"],
                     r["dominant"], r.get("useful_flops_ratio"),
                     r["memory"]["peak_bytes_per_device"] / 2 ** 30))
    if not rows:
        print("roofline,none,run `python -m repro.launch.dryrun --all` first,0")
        return
    print("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
          "dominant,useful_flops_ratio,peak_GiB")
    for row in rows:
        a, s, m, tc, tm, tl, dom, u, pk = row
        u = "" if u is None else f"{u:.2f}"
        print(f"{a},{s},{m},{tc:.4f},{tm:.4f},{tl:.4f},{dom},{u},{pk:.2f}")


def _git_sha() -> str | None:
    """Best-effort commit SHA of the repo the benchmark ran from."""
    import subprocess
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True, timeout=10,
                             cwd=Path(__file__).resolve().parent)
        return out.stdout.strip() if out.returncode == 0 else None
    except Exception:
        return None


def bench_provenance() -> dict:
    """The run's provenance header: enough to tell two BENCH_*.json apart
    without trusting the filename — device layout, library versions, the
    exact commit. Stamped into every artifact by ``write_bench_json``."""
    return {
        "device_count": jax.device_count(),
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "numpy_version": np.__version__,
        "git_sha": _git_sha(),
    }


def write_bench_json(path: str, rows: list) -> None:
    """Emit the machine-readable benchmark artifact (the BENCH_PR<N>.json
    CI artifact): rounds/sec per engine and scenario — including the
    heterogeneous GB–SVM-mix, membership-overhead and contributivity
    rows — with a provenance header, so CI tracks the perf trajectory
    across PRs and every artifact says which commit/devices produced it."""
    payload = {
        "schema": "gal-bench/v1",
        **bench_provenance(),
        "rows": rows,
    }
    Path(path).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {path} ({len(rows)} rows)")


def load_bench_json(path: str) -> dict:
    """Load a BENCH_*.json artifact from ANY PR generation, backfilling
    provenance fields older writers never stamped (``jax_version`` /
    ``numpy_version`` / ``git_sha`` arrive as None on PR4/PR5-era files)
    so downstream comparisons can treat every artifact uniformly.

    Rows are schema-checked: every row must be an object naming its
    ``scenario``, and any timing fields present must be numeric. Problem
    sizes older shard_scaling writers left implicit (``n`` / ``d`` /
    ``seconds``) are backfilled as None so consumers can select on them
    without per-generation special cases."""
    payload = json.loads(Path(path).read_text())
    if payload.get("schema") != "gal-bench/v1":
        raise ValueError(f"{path}: not a gal-bench/v1 artifact "
                         f"(schema={payload.get('schema')!r})")
    for field in ("device_count", "backend", "jax_version", "numpy_version",
                  "git_sha"):
        payload.setdefault(field, None)
    payload.setdefault("rows", [])
    if not isinstance(payload["rows"], list):
        raise ValueError(f"{path}: 'rows' must be a list")
    for i, row in enumerate(payload["rows"]):
        if not isinstance(row, dict) or not isinstance(
                row.get("scenario"), str):
            raise ValueError(f"{path}: row {i} is not an object with a "
                             f"'scenario' string")
        for field in ("seconds", "rounds_per_sec", "us_per_call"):
            if field in row and not isinstance(row[field], (int, float)):
                raise ValueError(f"{path}: row {i} field {field!r} is "
                                 f"not numeric")
        for field in ("n", "d", "seconds"):
            row.setdefault(field, None)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single table (table1..table6, fig4, table14)")
    ap.add_argument("--skip-tables", action="store_true")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="write the engine-benchmark rows as machine-"
                         "readable JSON with a provenance header (the "
                         "BENCH_PR<N>.json CI artifact)")
    ap.add_argument("--engines-only", action="store_true",
                    help="run only the GAL engine benchmarks (the fast "
                         "CI-artifact path): no tables, no micro, no "
                         "roofline")
    ap.add_argument("--full-shard-grid", action="store_true",
                    help="run the full placement grid (orgs up to 64, "
                         "65536-row cells) instead of the CI smoke slice "
                         "— the committed BENCH_PR8.json numbers")
    args = ap.parse_args()

    json_rows: list = []
    if args.engines_only:
        print("# gal engine benchmarks (name,us_per_round,derived)")
        gal_engine_benchmark(json_rows=json_rows)
        print("\n# gal artifact lifecycle: cold fit vs warm load "
              "(name,us,derived)")
        gal_artifact_benchmark(json_rows=json_rows)
        print("\n# gal membership + contributivity "
              "(name,us,derived)")
        gal_membership_benchmark(json_rows=json_rows)
        print("\n# gal shard engine scaling")
        gal_shard_scaling_benchmark(json_rows=json_rows,
                                    full=args.full_shard_grid)
        if args.json_out:
            write_bench_json(args.json_out, json_rows)
        return

    from benchmarks.tables import ALL_TABLES
    print("table,setting,metric,value,check")
    results = {}
    if not args.skip_tables:
        todo = ([args.only] if args.only else list(ALL_TABLES))
        for name in todo:
            t0 = time.time()
            ok = ALL_TABLES[name]()
            results[name] = ok
            print(f"# {name}: {'PASS' if ok else 'FAIL'} "
                  f"({time.time() - t0:.0f}s)", flush=True)

    print("\n# microbenchmarks: name,us_per_call,derived")
    micro_benchmarks()

    print("\n# gal engine: fused engines vs legacy python per scenario "
          "(name,us_per_round,derived)")
    gal_engine_benchmark(json_rows=json_rows)

    print("\n# gal artifact lifecycle: cold fit vs warm load "
          "(name,us,derived)")
    gal_artifact_benchmark(json_rows=json_rows)

    print("\n# gal membership + contributivity: dropout overhead and the "
          "LOO resume speedup (name,us,derived)")
    gal_membership_benchmark(json_rows=json_rows)

    print("\n# gal shard engine scaling: rounds/sec at forced host devices "
          "(name,us_per_round,derived)")
    gal_shard_scaling_benchmark(json_rows=json_rows,
                                full=args.full_shard_grid)

    print("\n# roofline table (from dry-run artifacts)")
    roofline_summary()

    if args.json_out:
        write_bench_json(args.json_out, json_rows)

    if results:
        n_pass = sum(results.values())
        print(f"\n# SUMMARY: {n_pass}/{len(results)} paper-claim checks PASS")
        if n_pass < len(results):
            raise SystemExit(1)


if __name__ == "__main__":
    main()
