#!/usr/bin/env python
"""Check that every relative markdown link in README.md and docs/*.md
resolves to a real file (anchors and external URLs are skipped; anchors
on relative links are stripped before the existence check).

Run from the repo root: ``python tools/check_links.py``. Exits non-zero
listing every dangling link — the CI docs job gates on it so the
serving/api/algorithm cross-links can never silently rot.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check(root: Path) -> list[str]:
    errors = []
    files = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    for md in files:
        if not md.exists():
            errors.append(f"{md}: file listed for checking does not exist")
            continue
        for lineno, line in enumerate(md.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:",
                                      "#")):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                resolved = (md.parent / rel).resolve()
                if not resolved.exists():
                    errors.append(
                        f"{md.relative_to(root)}:{lineno}: dangling link "
                        f"-> {target}")
    return errors


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    errors = check(root)
    if errors:
        print("\n".join(errors))
        print(f"{len(errors)} dangling link(s)")
        return 1
    n_files = 1 + len(list((root / "docs").glob("*.md")))
    print(f"all relative links resolve across {n_files} markdown files")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
